//! Versioned separation-matrix store and the live tenant health plane:
//! the coordinator's shared state.
//!
//! The training loop publishes B snapshots; concurrent readers (the
//! inference path, metric reporters, state dumps) read the latest version
//! without blocking the trainer. This mirrors the paper's deployment
//! story — the same hardware trains and *serves* (§I: "model creation,
//! training, and deployment in hardware").
//!
//! Beyond the separation matrix, every tenant publishes a
//! [`SessionStatus`] record (lifecycle phase, last Amari, drift events,
//! rollbacks, queue depth) into its [`StatusCell`] once per engine chunk
//! (the same points at which B snapshots are published), so
//! dashboards and the `serve-many --status-every` observer can watch a
//! fleet's health **while the hub is still running** — the live form of
//! the per-run counters that previously only appeared in the final
//! summary table.

use crate::linalg::Mat64;
use std::collections::BTreeMap;
use std::sync::{Arc, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Poison-tolerant read lock. The records behind these locks are plain
/// data (no invariants spanning multiple fields beyond what a single
/// `write` installs), so after a writer panics mid-update the worst a
/// reader sees is the panicking thread's last complete store — far
/// better than the whole health plane double-panicking while the
/// supervisor is trying to report the *first* fault.
fn read_lock<T>(lock: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    lock.read().unwrap_or_else(|e| e.into_inner())
}

/// Poison-tolerant write lock (see [`read_lock`]).
fn write_lock<T>(lock: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    lock.write().unwrap_or_else(|e| e.into_inner())
}

/// An immutable published snapshot.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// Monotonically increasing version (0 = initial).
    pub version: u64,
    /// Samples consumed when this snapshot was taken.
    pub samples: u64,
    /// The separation matrix.
    pub b: Mat64,
}

/// Shared, versioned store of the current separation matrix.
#[derive(Clone)]
pub struct StateStore {
    inner: Arc<RwLock<Snapshot>>,
}

impl StateStore {
    pub fn new(b0: Mat64) -> Self {
        Self { inner: Arc::new(RwLock::new(Snapshot { version: 0, samples: 0, b: b0 })) }
    }

    /// Publish a new snapshot; returns the new version.
    pub fn publish(&self, b: Mat64, samples: u64) -> u64 {
        let mut guard = write_lock(&self.inner);
        guard.version += 1;
        guard.samples = samples;
        guard.b = b;
        guard.version
    }

    /// Latest snapshot (cloned out; readers never hold the lock long).
    pub fn snapshot(&self) -> Snapshot {
        read_lock(&self.inner).clone()
    }

    /// Install a snapshot wholesale (detach-to-disk restore). Subsequent
    /// publishes continue the version sequence from the restored point, so
    /// a restored session's version trajectory matches an uninterrupted
    /// run of the same stream.
    pub fn restore(&self, snap: Snapshot) {
        *write_lock(&self.inner) = snap;
    }

    /// Latest version number.
    pub fn version(&self) -> u64 {
        read_lock(&self.inner).version
    }

    /// Apply the current separation matrix: `y = B x`.
    pub fn separate(&self, x: &[f64]) -> Vec<f64> {
        let snap = self.snapshot();
        snap.b.matvec(x)
    }
}

/// Lifecycle phase of a serving-plane session (DESIGN.md §Session
/// lifecycle state machine).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionPhase {
    /// Admitted by placement; the shard has not installed the runner yet.
    Admitted,
    /// Streaming: the shard worker is applying this tenant's samples.
    Streaming,
    /// Producer gated; already-queued samples still drain, nothing new
    /// is ingested until resume.
    Paused,
    /// Parked: the runner was removed from its shard and is held by the
    /// control plane, ready to re-attach (on any shard) bit-identically.
    Detached,
    /// The supervisor is rebuilding this tenant after its hosting shard
    /// worker panicked; the shard worker's install promotes it back to
    /// `Streaming` once the replacement runner is attached.
    Restarting,
    /// Terminal: the numeric-fault guard tripped repeatedly (non-finite
    /// separator surviving the rollback/reset retry budget) and the
    /// tenant was pulled off its shard for operator inspection.
    Quarantined,
    /// Terminal: the session's stream ended (or the hub drained it).
    Drained,
}

impl SessionPhase {
    /// Short lowercase label for status tables.
    pub fn name(self) -> &'static str {
        match self {
            Self::Admitted => "admitted",
            Self::Streaming => "streaming",
            Self::Paused => "paused",
            Self::Detached => "detached",
            Self::Restarting => "restarting",
            Self::Quarantined => "quarantined",
            Self::Drained => "drained",
        }
    }

    /// Terminal phases never transition again (a racing control-plane
    /// write cannot resurrect a finished or quarantined session).
    pub fn is_terminal(self) -> bool {
        matches!(self, Self::Drained | Self::Quarantined)
    }
}

/// One tenant's live health record, published by the session runner once
/// per engine chunk and readable through [`StateDirectory::status`] while
/// training runs.
#[derive(Clone, Debug)]
pub struct SessionStatus {
    /// Session id (the directory key).
    pub id: u64,
    /// Session name (from its config).
    pub name: String,
    /// Shard currently hosting the runner (last shard when detached).
    pub shard: usize,
    /// Lifecycle phase.
    pub phase: SessionPhase,
    /// Samples applied to the separator so far.
    pub samples: u64,
    /// Most recent monitored Amari index (NaN before the first record).
    pub last_amari: f64,
    /// Divergence-guard resets so far.
    pub resets: u64,
    /// Drift events the adaptive control plane has raised so far.
    pub drift_events: u64,
    /// Checkpoint rollbacks served so far (subset of `resets`).
    pub rollbacks: u64,
    /// Shard ingest backlog observed when this tenant's last block was
    /// dequeued (messages; see `HubMetrics::queue_depth` semantics).
    pub queue_depth: usize,
    /// Cumulative fixed-point saturation-latch events (`qfx` rail clamps
    /// and non-finite quantizations) this tenant's engine has recorded.
    /// Always 0 for floating-point tenants; for q16/q32 tenants this is
    /// the divergence-surveillance signal (their values are never NaN).
    pub saturations: u64,
    /// Peak cohort pool width this tenant has shared a fused kernel with
    /// (lanes, including itself). 0 = never cohort-eligible (per-session
    /// path throughout); 1 = eligible but so far alone in its pool; ≥ 2 =
    /// actually shared lane-level SIMD work. Monotone — it survives pool
    /// churn so finish-time occupancy accounting still sees it.
    pub pool: usize,
    /// Why this tenant was quarantined (None while healthy).
    pub fault: Option<String>,
}

impl SessionStatus {
    fn new(id: u64, name: &str) -> Self {
        Self {
            id,
            name: name.to_string(),
            shard: 0,
            phase: SessionPhase::Admitted,
            samples: 0,
            last_amari: f64::NAN,
            resets: 0,
            drift_events: 0,
            rollbacks: 0,
            queue_depth: 0,
            saturations: 0,
            pool: 0,
            fault: None,
        }
    }
}

/// Shared, cloneable handle to one tenant's [`SessionStatus`] record.
///
/// Every write replaces the full set of progress fields under one write
/// lock, so concurrent readers can never observe a torn record (e.g. a
/// drift count from one chunk paired with a sample count from another) —
/// pinned by the seeded stress test in this module.
#[derive(Clone)]
pub struct StatusCell {
    inner: Arc<RwLock<SessionStatus>>,
}

impl StatusCell {
    pub fn new(id: u64, name: &str) -> Self {
        Self { inner: Arc::new(RwLock::new(SessionStatus::new(id, name))) }
    }

    /// Current record (cloned out; readers never hold the lock long).
    pub fn snapshot(&self) -> SessionStatus {
        read_lock(&self.inner).clone()
    }

    /// Set the lifecycle phase (control-plane transitions). `Drained`
    /// and `Quarantined` are terminal: once a session's stream ended (or
    /// its numeric fault was declared), a racing pause/detach on the
    /// control plane cannot flip the published phase back to a live
    /// state.
    pub fn set_phase(&self, phase: SessionPhase) {
        let mut s = write_lock(&self.inner);
        if !s.phase.is_terminal() {
            s.phase = phase;
        }
    }

    /// Move to the terminal `Quarantined` phase and record why — one
    /// write lock, so a reader never sees the phase without its reason.
    pub fn quarantine(&self, reason: &str) {
        let mut s = write_lock(&self.inner);
        if !s.phase.is_terminal() {
            s.phase = SessionPhase::Quarantined;
            s.fault = Some(reason.to_string());
        }
    }

    /// Record the shard currently hosting the runner.
    pub fn set_shard(&self, shard: usize) {
        write_lock(&self.inner).shard = shard;
    }

    /// Promote to `Streaming` only from a fresh (`Admitted`), parked
    /// (`Detached`) or supervisor-rebuilt (`Restarting`) phase — the
    /// shard worker's install-time transition. Check-and-set under one
    /// write lock, so it can never clobber a concurrent control-plane
    /// `Paused` (or a terminal `Drained`/`Quarantined`).
    pub fn promote_to_streaming(&self) {
        let mut s = write_lock(&self.inner);
        if matches!(
            s.phase,
            SessionPhase::Admitted | SessionPhase::Detached | SessionPhase::Restarting
        ) {
            s.phase = SessionPhase::Streaming;
        }
    }

    /// Publish one coherent progress record (the runner's per-chunk
    /// write): all fields land under a single lock.
    pub fn publish_progress(
        &self,
        samples: u64,
        last_amari: f64,
        resets: u64,
        drift_events: u64,
        rollbacks: u64,
        queue_depth: usize,
        saturations: u64,
    ) {
        let mut s = write_lock(&self.inner);
        s.samples = samples;
        if last_amari.is_finite() {
            s.last_amari = last_amari;
        }
        s.resets = resets;
        s.drift_events = drift_events;
        s.rollbacks = rollbacks;
        s.queue_depth = queue_depth;
        s.saturations = saturations;
    }

    /// Record the width of the cohort pool this tenant currently shares
    /// (the executor publishes on every admission). Monotone max: the
    /// record keeps the *peak* width, so occupancy accounting at finish
    /// time still sees sessions whose pool-mates already drained.
    pub fn set_pool_width(&self, width: usize) {
        let mut s = write_lock(&self.inner);
        s.pool = s.pool.max(width);
    }
}

/// One coherent view of the shard autoscaler: lifetime spawn/retire
/// counts, the live shard count, and the latest per-shard ingest pressure
/// (queue depth over capacity, in [0, 1]).
#[derive(Clone, Debug, Default)]
pub struct AutoscaleSnapshot {
    /// Workers spawned by the autoscaler over the hub's lifetime.
    pub spawns: u64,
    /// Workers retired by the autoscaler over the hub's lifetime.
    pub retires: u64,
    /// Shards currently live (0 until the autoscaler first publishes).
    pub active_shards: usize,
    /// Latest pressure reading per shard slot (NaN for retired slots).
    pub pressure: Vec<f64>,
}

/// Shared, cloneable feed of autoscaler decisions — written by the hub's
/// `autoscale_tick`, read by the `serve-many` observer and the status
/// table so scaling activity is visible while the fleet runs.
#[derive(Clone, Default)]
pub struct AutoscaleLog {
    inner: Arc<RwLock<AutoscaleSnapshot>>,
}

impl AutoscaleLog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish the live shard count and per-slot pressure readings.
    pub fn publish(&self, active_shards: usize, pressure: Vec<f64>) {
        let mut g = write_lock(&self.inner);
        g.active_shards = active_shards;
        g.pressure = pressure;
    }

    /// Count a scale-up decision.
    pub fn note_spawn(&self) {
        write_lock(&self.inner).spawns += 1;
    }

    /// Count a scale-down decision.
    pub fn note_retire(&self) {
        write_lock(&self.inner).retires += 1;
    }

    /// Current view (cloned out; readers never hold the lock long).
    pub fn snapshot(&self) -> AutoscaleSnapshot {
        read_lock(&self.inner).clone()
    }
}

/// One coherent view of the fault-domain supervisor: lifetime shard
/// fault/restart counts (total and per slot), tenant quarantines, and
/// the most recent fault reason — the health plane's "what broke last"
/// record.
#[derive(Clone, Debug, Default)]
pub struct SupervisorSnapshot {
    /// Shard worker faults handled (each one triggers a respawn attempt
    /// unless the slot's restart budget is exhausted).
    pub restarts: u64,
    /// Tenants moved to the terminal `Quarantined` phase.
    pub quarantines: u64,
    /// Fault/restart count per shard slot (index = slot).
    pub per_shard: Vec<u64>,
    /// Human-readable reason of the most recent fault (panic message or
    /// quarantine cause).
    pub last_fault: Option<String>,
}

/// Shared, cloneable feed of supervisor decisions — written by the hub's
/// `supervise_tick` and quarantine path, read by the `serve-many`
/// observer and the status table so operators see degradation, not just
/// throughput.
#[derive(Clone, Default)]
pub struct SupervisorLog {
    inner: Arc<RwLock<SupervisorSnapshot>>,
}

impl SupervisorLog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a shard worker fault (and the respawn it triggers).
    pub fn note_shard_fault(&self, shard: usize, reason: &str) {
        let mut g = write_lock(&self.inner);
        g.restarts += 1;
        if g.per_shard.len() <= shard {
            g.per_shard.resize(shard + 1, 0);
        }
        g.per_shard[shard] += 1;
        g.last_fault = Some(reason.to_string());
    }

    /// Record a tenant quarantine.
    pub fn note_quarantine(&self, reason: &str) {
        let mut g = write_lock(&self.inner);
        g.quarantines += 1;
        g.last_fault = Some(reason.to_string());
    }

    /// Current view (cloned out; readers never hold the lock long).
    pub fn snapshot(&self) -> SupervisorSnapshot {
        read_lock(&self.inner).clone()
    }
}

/// One registered tenant: separation matrix plus health record.
#[derive(Clone)]
struct Tenant {
    store: StateStore,
    status: StatusCell,
}

/// Session-id → per-tenant state registry for multi-tenant serving.
///
/// The hub registers every session's [`StateStore`] **and**
/// [`StatusCell`] here so concurrent readers (inference, dashboards) can
/// resolve any tenant's latest separation matrix and live health without
/// touching the training path. Cloning shares the map.
#[derive(Clone, Default)]
pub struct StateDirectory {
    inner: Arc<RwLock<BTreeMap<u64, Tenant>>>,
    autoscale: AutoscaleLog,
    supervisor: SupervisorLog,
}

impl StateDirectory {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or replace) a session's store with a fresh, anonymous
    /// status cell. Prefer [`StateDirectory::register`] on the serving
    /// path so the health plane carries the session's real identity.
    pub fn insert(&self, session: u64, store: StateStore) {
        self.register(session, store, StatusCell::new(session, ""));
    }

    /// Register (or replace) a session's store and status cell.
    pub fn register(&self, session: u64, store: StateStore, status: StatusCell) {
        write_lock(&self.inner).insert(session, Tenant { store, status });
    }

    /// Look up a session's store (cheap clone; stores share state).
    pub fn get(&self, session: u64) -> Option<StateStore> {
        read_lock(&self.inner).get(&session).map(|t| t.store.clone())
    }

    /// Look up a session's live health record.
    pub fn status(&self, session: u64) -> Option<SessionStatus> {
        read_lock(&self.inner).get(&session).map(|t| t.status.snapshot())
    }

    /// Every tenant's current health record, ascending by id.
    pub fn statuses(&self) -> Vec<SessionStatus> {
        read_lock(&self.inner).values().map(|t| t.status.snapshot()).collect()
    }

    /// The autoscaler's shared decision feed (the hub writes, observers
    /// read).
    pub fn autoscale_log(&self) -> AutoscaleLog {
        self.autoscale.clone()
    }

    /// The fault-domain supervisor's shared decision feed (the hub
    /// writes, observers read).
    pub fn supervisor_log(&self) -> SupervisorLog {
        self.supervisor.clone()
    }

    /// Render the live fleet-health table (`serve-many --status-every`).
    /// The `sat` column is the tenant's cumulative fixed-point
    /// saturation-latch count (`-` while zero — always, for float
    /// tenants); the `pool` column is the tenant's peak cohort pool
    /// width (`-` for tenants that never took the cohort path); the
    /// `press` column is the hosting shard's latest ingest
    /// pressure as seen by the autoscaler (`-` until it publishes a
    /// reading); the `faults` column is the hosting shard's worker
    /// fault/restart count (`-` while zero). Footers summarize scaling
    /// and supervision activity once any occurred.
    pub fn render_status_table(&self) -> String {
        let scale = self.autoscale.snapshot();
        let sup = self.supervisor.snapshot();
        let mut out = String::new();
        out.push_str(
            "session  phase        shard    samples    amari  resets  drifts  rollbk  depth  \
             sat  pool  press  faults\n",
        );
        for s in self.statuses() {
            let sat = match s.saturations {
                0 => format!("{:>3}", "-"),
                n => format!("{n:>3}"),
            };
            let pool = match s.pool {
                0 => format!("{:>4}", "-"),
                w => format!("{w:>4}"),
            };
            let press = match scale.pressure.get(s.shard) {
                Some(p) if p.is_finite() => format!("{p:>5.2}"),
                _ => format!("{:>5}", "-"),
            };
            let faults = match sup.per_shard.get(s.shard) {
                Some(&n) if n > 0 => format!("{n:>6}"),
                _ => format!("{:>6}", "-"),
            };
            out.push_str(&format!(
                "{:>7}  {:<11}  {:>5}  {:>9}  {:>7.4}  {:>6}  {:>6}  {:>6}  {:>5}  {}  {}  {}  \
                 {}\n",
                s.id,
                s.phase.name(),
                s.shard,
                s.samples,
                s.last_amari,
                s.resets,
                s.drift_events,
                s.rollbacks,
                s.queue_depth,
                sat,
                pool,
                press,
                faults
            ));
        }
        if scale.active_shards > 0 || scale.spawns > 0 || scale.retires > 0 {
            out.push_str(&format!(
                "autoscaler: shards={} spawns={} retires={}\n",
                scale.active_shards, scale.spawns, scale.retires
            ));
        }
        if sup.restarts > 0 || sup.quarantines > 0 {
            out.push_str(&format!(
                "supervisor: restarts={} quarantined={} last_fault={}\n",
                sup.restarts,
                sup.quarantines,
                sup.last_fault.as_deref().unwrap_or("-")
            ));
        }
        out
    }

    /// Registered session ids, ascending.
    pub fn sessions(&self) -> Vec<u64> {
        read_lock(&self.inner).keys().copied().collect()
    }

    pub fn len(&self) -> usize {
        read_lock(&self.inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fraction of cohort-eligible tenants that actually shared a fused
    /// kernel with at least one other lane (peak pool width ≥ 2), over
    /// tenants that ever took the cohort path (peak width ≥ 1). 0.0 when
    /// no tenant was cohort-eligible. This is the fleet's *pool
    /// occupancy* — the signal shape-aware placement tries to raise.
    pub fn pool_occupancy(&self) -> f64 {
        let statuses = self.statuses();
        let eligible = statuses.iter().filter(|s| s.pool >= 1).count();
        if eligible == 0 {
            return 0.0;
        }
        let sharing = statuses.iter().filter(|s| s.pool >= 2).count();
        sharing as f64 / eligible as f64
    }

    /// Ids of every tenant currently in the terminal `Quarantined`
    /// phase (fault accounting for drills and operators).
    pub fn quarantined(&self) -> Vec<u64> {
        self.statuses()
            .into_iter()
            .filter(|s| s.phase == SessionPhase::Quarantined)
            .map(|s| s.id)
            .collect()
    }

    /// Apply session `id`'s current separation matrix: `y = B x`.
    pub fn separate(&self, session: u64, x: &[f64]) -> Option<Vec<f64>> {
        self.get(session).map(|s| s.separate(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn publish_bumps_version() {
        let st = StateStore::new(Mat64::eye(2, 4));
        assert_eq!(st.version(), 0);
        st.publish(Mat64::zeros(2, 4), 10);
        assert_eq!(st.version(), 1);
        let snap = st.snapshot();
        assert_eq!(snap.samples, 10);
        assert_eq!(snap.b, Mat64::zeros(2, 4));
    }

    #[test]
    fn separate_uses_latest() {
        let st = StateStore::new(Mat64::eye(2, 2));
        assert_eq!(st.separate(&[3.0, 4.0]), vec![3.0, 4.0]);
        let mut flip = Mat64::zeros(2, 2);
        flip[(0, 1)] = 1.0;
        flip[(1, 0)] = 1.0;
        st.publish(flip, 1);
        assert_eq!(st.separate(&[3.0, 4.0]), vec![4.0, 3.0]);
    }

    #[test]
    fn directory_routes_sessions() {
        let dir = StateDirectory::new();
        assert!(dir.is_empty());
        let a = StateStore::new(Mat64::eye(2, 2));
        let mut flip = Mat64::zeros(2, 2);
        flip[(0, 1)] = 1.0;
        flip[(1, 0)] = 1.0;
        let b = StateStore::new(flip);
        dir.insert(0, a.clone());
        dir.insert(7, b);
        assert_eq!(dir.sessions(), vec![0, 7]);
        assert_eq!(dir.separate(0, &[3.0, 4.0]), Some(vec![3.0, 4.0]));
        assert_eq!(dir.separate(7, &[3.0, 4.0]), Some(vec![4.0, 3.0]));
        assert_eq!(dir.separate(9, &[3.0, 4.0]), None);
        // The directory shares state with the trainer's handle.
        a.publish(Mat64::zeros(2, 2), 5);
        assert_eq!(dir.get(0).unwrap().version(), 1);
    }

    #[test]
    fn status_cell_publishes_coherent_records() {
        let cell = StatusCell::new(3, "tenant");
        let s = cell.snapshot();
        assert_eq!((s.id, s.name.as_str()), (3, "tenant"));
        assert_eq!(s.phase, SessionPhase::Admitted);
        assert!(s.last_amari.is_nan(), "no amari before the first record");
        cell.set_phase(SessionPhase::Streaming);
        cell.set_shard(1);
        cell.publish_progress(512, 0.25, 1, 2, 1, 7, 42);
        let s = cell.snapshot();
        assert_eq!(s.phase, SessionPhase::Streaming);
        assert_eq!((s.shard, s.samples, s.queue_depth), (1, 512, 7));
        assert_eq!((s.resets, s.drift_events, s.rollbacks), (1, 2, 1));
        assert_eq!(s.saturations, 42);
        assert_eq!(s.last_amari, 0.25);
        // A NaN amari (no ground truth yet) keeps the previous value.
        cell.publish_progress(1024, f64::NAN, 1, 2, 1, 0, 42);
        assert_eq!(cell.snapshot().last_amari, 0.25);
        assert_eq!(cell.snapshot().samples, 1024);
        // Drained is terminal: a racing control-plane transition can
        // never resurrect a finished session's published phase.
        cell.set_phase(SessionPhase::Drained);
        cell.set_phase(SessionPhase::Paused);
        assert_eq!(cell.snapshot().phase, SessionPhase::Drained);
    }

    #[test]
    fn promote_to_streaming_is_conditional() {
        // The worker's install-time transition only fires from Admitted
        // or Detached: a pause that raced ahead of the install (or a
        // terminal drain) is never clobbered.
        let cell = StatusCell::new(0, "t");
        cell.promote_to_streaming();
        assert_eq!(cell.snapshot().phase, SessionPhase::Streaming, "Admitted promotes");
        cell.set_phase(SessionPhase::Detached);
        cell.promote_to_streaming();
        assert_eq!(cell.snapshot().phase, SessionPhase::Streaming, "Detached promotes");
        cell.set_phase(SessionPhase::Paused);
        cell.promote_to_streaming();
        assert_eq!(cell.snapshot().phase, SessionPhase::Paused, "Paused survives");
        cell.set_phase(SessionPhase::Drained);
        cell.promote_to_streaming();
        assert_eq!(cell.snapshot().phase, SessionPhase::Drained, "Drained survives");
    }

    #[test]
    fn quarantine_is_terminal_and_carries_its_reason() {
        let cell = StatusCell::new(9, "bad");
        cell.set_phase(SessionPhase::Streaming);
        cell.quarantine("non-finite separator after 3 rollback attempts");
        let s = cell.snapshot();
        assert_eq!(s.phase, SessionPhase::Quarantined);
        assert_eq!(s.fault.as_deref(), Some("non-finite separator after 3 rollback attempts"));
        // Terminal: neither a control-plane transition, a worker install,
        // nor a second quarantine can move or re-label it.
        cell.set_phase(SessionPhase::Streaming);
        cell.promote_to_streaming();
        cell.quarantine("other");
        let s = cell.snapshot();
        assert_eq!(s.phase, SessionPhase::Quarantined);
        assert_eq!(s.fault.as_deref(), Some("non-finite separator after 3 rollback attempts"));
        // A drained session never becomes quarantined after the fact.
        let done = StatusCell::new(1, "ok");
        done.set_phase(SessionPhase::Drained);
        done.quarantine("late");
        assert_eq!(done.snapshot().phase, SessionPhase::Drained);
        assert!(done.snapshot().fault.is_none());
    }

    #[test]
    fn restarting_promotes_to_streaming() {
        // The supervisor parks a tenant in Restarting while it rebuilds
        // the runner; the replacement shard's install must promote it.
        let cell = StatusCell::new(2, "t");
        cell.set_phase(SessionPhase::Streaming);
        cell.set_phase(SessionPhase::Restarting);
        assert_eq!(cell.snapshot().phase, SessionPhase::Restarting);
        cell.promote_to_streaming();
        assert_eq!(cell.snapshot().phase, SessionPhase::Streaming);
    }

    #[test]
    fn supervisor_log_feeds_status_table() {
        let dir = StateDirectory::new();
        let cell = StatusCell::new(1, "t1");
        dir.register(1, StateStore::new(Mat64::eye(2, 2)), cell.clone());
        cell.set_shard(0);
        let table = dir.render_status_table();
        assert!(table.contains("faults"), "{table}");
        assert!(!table.contains("supervisor:"), "no footer before activity: {table}");
        let log = dir.supervisor_log();
        log.note_shard_fault(0, "shard worker panicked: injected");
        log.note_quarantine("tenant 9: non-finite separator");
        let snap = log.snapshot();
        assert_eq!((snap.restarts, snap.quarantines), (1, 1));
        assert_eq!(snap.per_shard, vec![1]);
        let table = dir.render_status_table();
        assert!(
            table.contains(
                "supervisor: restarts=1 quarantined=1 last_fault=tenant 9: non-finite separator"
            ),
            "{table}"
        );
        // Tenant 1 sits on shard 0, which has one recorded fault.
        let row = table.lines().nth(1).expect("tenant row");
        assert!(row.trim_end().ends_with('1'), "faults column: {row:?}");
        // The log handle is shared through directory clones.
        assert_eq!(dir.clone().supervisor_log().snapshot().restarts, 1);
        assert_eq!(dir.quarantined(), Vec::<u64>::new());
        cell.quarantine("non-finite");
        assert_eq!(dir.quarantined(), vec![1]);
    }

    #[test]
    fn directory_serves_statuses() {
        let dir = StateDirectory::new();
        let store = StateStore::new(Mat64::eye(2, 2));
        let cell = StatusCell::new(5, "t5");
        dir.register(5, store, cell.clone());
        cell.set_phase(SessionPhase::Streaming);
        cell.publish_progress(100, 0.5, 0, 0, 0, 0, 0);
        let s = dir.status(5).expect("registered");
        assert_eq!(s.name, "t5");
        assert_eq!(s.samples, 100);
        assert!(dir.status(6).is_none());
        assert_eq!(dir.statuses().len(), 1);
        let table = dir.render_status_table();
        assert!(table.contains("streaming"), "{table}");
        // `insert` still registers an (anonymous) health record.
        dir.insert(6, StateStore::new(Mat64::eye(2, 2)));
        assert_eq!(dir.status(6).unwrap().phase, SessionPhase::Admitted);
    }

    #[test]
    fn saturation_column_renders_only_when_latched() {
        let dir = StateDirectory::new();
        let cell = StatusCell::new(1, "q16-tenant");
        dir.register(1, StateStore::new(Mat64::eye(2, 2)), cell.clone());
        let table = dir.render_status_table();
        assert!(table.contains("sat"), "header carries the sat column: {table}");
        let row = table.lines().nth(1).expect("tenant row");
        // Zero events (every float tenant, healthy q16 tenants) shows '-'.
        let dashes = row.matches('-').count();
        cell.publish_progress(64, 0.5, 0, 0, 0, 0, 17);
        let row = dir.render_status_table().lines().nth(1).unwrap().to_string();
        assert!(row.contains(" 17 "), "latched count surfaces: {row:?}");
        assert_eq!(row.matches('-').count(), dashes - 1, "sat dash replaced: {row:?}");
    }

    #[test]
    fn pool_column_and_occupancy_track_peak_widths() {
        let dir = StateDirectory::new();
        let a = StatusCell::new(1, "cohort-a");
        let b = StatusCell::new(2, "cohort-b");
        let c = StatusCell::new(3, "solo");
        for (id, cell) in [(1, &a), (2, &b), (3, &c)] {
            dir.register(id, StateStore::new(Mat64::eye(2, 2)), cell.clone());
        }
        // Nobody took the cohort path yet: all dashes, occupancy 0.
        assert_eq!(dir.pool_occupancy(), 0.0);
        // a and b share a 2-lane pool; c stays per-session (pool = 0).
        a.set_pool_width(1);
        a.set_pool_width(2);
        b.set_pool_width(2);
        let table = dir.render_status_table();
        assert!(table.contains("pool"), "header carries the pool column: {table}");
        let row_a = table.lines().nth(1).expect("tenant row");
        assert!(row_a.contains("  2  "), "peak width surfaces: {row_a:?}");
        assert_eq!(dir.pool_occupancy(), 1.0, "both eligible tenants share");
        // Peak is monotone: a shrink back to a lone lane is not recorded.
        a.set_pool_width(1);
        assert_eq!(dir.status(1).unwrap().pool, 2);
        // An eligible-but-alone tenant halves occupancy.
        c.set_pool_width(1);
        assert!((dir.pool_occupancy() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn restore_installs_snapshot_wholesale() {
        let st = StateStore::new(Mat64::eye(2, 2));
        st.publish(Mat64::zeros(2, 2), 10);
        st.restore(Snapshot { version: 42, samples: 1000, b: Mat64::eye(2, 2) });
        assert_eq!(st.version(), 42);
        assert_eq!(st.snapshot().samples, 1000);
        // Publishes continue the restored version sequence.
        assert_eq!(st.publish(Mat64::zeros(2, 2), 1100), 43);
    }

    #[test]
    fn autoscale_log_feeds_status_table() {
        let dir = StateDirectory::new();
        let cell = StatusCell::new(1, "t1");
        dir.register(1, StateStore::new(Mat64::eye(2, 2)), cell.clone());
        cell.set_shard(0);
        let table = dir.render_status_table();
        assert!(table.contains("press"), "{table}");
        assert!(!table.contains("autoscaler:"), "no footer before activity: {table}");
        let log = dir.autoscale_log();
        log.note_spawn();
        log.publish(2, vec![0.84, 0.12]);
        let table = dir.render_status_table();
        assert!(table.contains("0.84"), "{table}");
        assert!(table.contains("autoscaler: shards=2 spawns=1 retires=0"), "{table}");
        // The log handle is shared through directory clones.
        assert_eq!(dir.clone().autoscale_log().snapshot().spawns, 1);
    }

    #[test]
    fn status_and_state_reads_are_never_torn() {
        // Satellite stress test: shard-side writers publish *correlated*
        // records — every StateStore publish writes B ≡ k with samples = k,
        // every StatusCell publish writes samples = drifts = rollbacks = k
        // — while readers hop between tenants on a seeded schedule. Any
        // torn (partially updated) record breaks the correlation.
        use crate::signal::Pcg32;
        const TENANTS: u64 = 4;
        const WRITES: u64 = 2_000;
        let dir = StateDirectory::new();
        let mut cells = Vec::new();
        let mut stores = Vec::new();
        for id in 0..TENANTS {
            let store = StateStore::new(Mat64::zeros(2, 2));
            let cell = StatusCell::new(id, &format!("t{id}"));
            dir.register(id, store.clone(), cell.clone());
            stores.push(store);
            cells.push(cell);
        }

        let writers: Vec<_> = (0..TENANTS)
            .map(|id| {
                let store = stores[id as usize].clone();
                let cell = cells[id as usize].clone();
                thread::spawn(move || {
                    for k in 1..=WRITES {
                        let b = Mat64::from_fn(2, 2, |_, _| k as f64);
                        store.publish(b, k);
                        cell.publish_progress(k, 0.1, k, k, k, k as usize, k);
                    }
                })
            })
            .collect();

        let readers: Vec<_> = (0..4u64)
            .map(|seed| {
                let dir = dir.clone();
                thread::spawn(move || {
                    let mut rng = Pcg32::seed(0x7EA2 ^ seed);
                    let mut last_version = vec![0u64; TENANTS as usize];
                    for _ in 0..4_000 {
                        let id = rng.below(TENANTS as u32) as u64;
                        let snap = dir.get(id).unwrap().snapshot();
                        // B and samples were written together: all four
                        // elements equal the sample count (or the initial
                        // zero state).
                        for r in 0..2 {
                            for c in 0..2 {
                                assert_eq!(
                                    snap.b[(r, c)],
                                    snap.samples as f64,
                                    "torn StateStore snapshot for tenant {id}"
                                );
                            }
                        }
                        assert!(
                            snap.version >= last_version[id as usize],
                            "version went backwards"
                        );
                        last_version[id as usize] = snap.version;
                        let st = dir.status(id).unwrap();
                        assert_eq!(
                            (st.samples, st.samples),
                            (st.drift_events, st.rollbacks),
                            "torn SessionStatus record for tenant {id}"
                        );
                        assert_eq!(st.resets, st.samples);
                        assert_eq!(st.saturations, st.samples, "torn saturation count");
                    }
                })
            })
            .collect();

        for w in writers {
            w.join().unwrap();
        }
        for r in readers {
            r.join().unwrap();
        }
        for id in 0..TENANTS {
            assert_eq!(dir.get(id).unwrap().snapshot().samples, WRITES);
            assert_eq!(dir.status(id).unwrap().samples, WRITES);
        }
    }

    #[test]
    fn concurrent_readers_and_writer() {
        let st = StateStore::new(Mat64::eye(2, 4));
        let writer = {
            let st = st.clone();
            thread::spawn(move || {
                for i in 1..=100u64 {
                    st.publish(Mat64::eye(2, 4), i);
                }
            })
        };
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let st = st.clone();
                thread::spawn(move || {
                    let mut last = 0;
                    for _ in 0..200 {
                        let v = st.version();
                        assert!(v >= last, "version went backwards");
                        last = v;
                    }
                })
            })
            .collect();
        writer.join().unwrap();
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(st.version(), 100);
    }
}
