//! Versioned separation-matrix store: the coordinator's shared state.
//!
//! The training loop publishes B snapshots; concurrent readers (the
//! inference path, metric reporters, state dumps) read the latest version
//! without blocking the trainer. This mirrors the paper's deployment
//! story — the same hardware trains and *serves* (§I: "model creation,
//! training, and deployment in hardware").

use crate::linalg::Mat64;
use std::sync::{Arc, RwLock};

/// An immutable published snapshot.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// Monotonically increasing version (0 = initial).
    pub version: u64,
    /// Samples consumed when this snapshot was taken.
    pub samples: u64,
    /// The separation matrix.
    pub b: Mat64,
}

/// Shared, versioned store of the current separation matrix.
#[derive(Clone)]
pub struct StateStore {
    inner: Arc<RwLock<Snapshot>>,
}

impl StateStore {
    pub fn new(b0: Mat64) -> Self {
        Self { inner: Arc::new(RwLock::new(Snapshot { version: 0, samples: 0, b: b0 })) }
    }

    /// Publish a new snapshot; returns the new version.
    pub fn publish(&self, b: Mat64, samples: u64) -> u64 {
        let mut guard = self.inner.write().expect("state lock poisoned");
        guard.version += 1;
        guard.samples = samples;
        guard.b = b;
        guard.version
    }

    /// Latest snapshot (cloned out; readers never hold the lock long).
    pub fn snapshot(&self) -> Snapshot {
        self.inner.read().expect("state lock poisoned").clone()
    }

    /// Latest version number.
    pub fn version(&self) -> u64 {
        self.inner.read().expect("state lock poisoned").version
    }

    /// Apply the current separation matrix: `y = B x`.
    pub fn separate(&self, x: &[f64]) -> Vec<f64> {
        let snap = self.snapshot();
        snap.b.matvec(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn publish_bumps_version() {
        let st = StateStore::new(Mat64::eye(2, 4));
        assert_eq!(st.version(), 0);
        st.publish(Mat64::zeros(2, 4), 10);
        assert_eq!(st.version(), 1);
        let snap = st.snapshot();
        assert_eq!(snap.samples, 10);
        assert_eq!(snap.b, Mat64::zeros(2, 4));
    }

    #[test]
    fn separate_uses_latest() {
        let st = StateStore::new(Mat64::eye(2, 2));
        assert_eq!(st.separate(&[3.0, 4.0]), vec![3.0, 4.0]);
        let mut flip = Mat64::zeros(2, 2);
        flip[(0, 1)] = 1.0;
        flip[(1, 0)] = 1.0;
        st.publish(flip, 1);
        assert_eq!(st.separate(&[3.0, 4.0]), vec![4.0, 3.0]);
    }

    #[test]
    fn concurrent_readers_and_writer() {
        let st = StateStore::new(Mat64::eye(2, 4));
        let writer = {
            let st = st.clone();
            thread::spawn(move || {
                for i in 1..=100u64 {
                    st.publish(Mat64::eye(2, 4), i);
                }
            })
        };
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let st = st.clone();
                thread::spawn(move || {
                    let mut last = 0;
                    for _ in 0..200 {
                        let v = st.version();
                        assert!(v >= last, "version went backwards");
                        last = v;
                    }
                })
            })
            .collect();
        writer.join().unwrap();
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(st.version(), 100);
    }
}
