//! Streaming server: the end-to-end orchestration loop.
//!
//! Topology (std threads + bounded channels — the channel *is* the
//! backpressure: a slow engine stalls the producer exactly like a full
//! input FIFO stalls the FPGA front-end):
//!
//! ```text
//!   producer thread                consumer (caller thread)
//!   MixedStream ──► SyncSender ──► Chunker ──► Engine ──► StateStore
//!        │                                        │
//!        └── periodic Mixing(A) events ──────► Monitor (Amari history)
//! ```

use super::batcher::Chunker;
use super::engine::{CohortLane, Engine};
use super::monitor::{Monitor, MonitorPoint};
use super::state::{SessionPhase, Snapshot, StateStore, StatusCell};
use crate::adapt::AdaptiveController;
use crate::config::ExperimentConfig;
use crate::ica::{ConvergenceCriterion, Nonlinearity};
use crate::linalg::Mat64;
use crate::signal::{
    DriftOnsetMixing, MixedStream, NanBurstMixing, Pcg32, RotatingMixing, SourceBank,
    StaticMixing, SwitchOnceMixing, SwitchingMixing,
};
use anyhow::{bail, Context, Result};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread;
use std::time::Instant;

/// Events flowing from a producer into a session's consumer.
///
/// Samples travel in row-major *blocks* rather than per-sample `Vec`s:
/// one allocation and one channel operation per `PRODUCER_BLOCK` samples
/// (EXPERIMENTS.md §Perf iteration 1 — 3-4× end-to-end throughput).
/// Shared between the single-stream server and the multi-session hub
/// (`hub.rs`), which tags each event with a session id.
pub(crate) enum StreamEvent {
    /// A block of observation samples (rows × m).
    Batch(Mat64),
    /// Ground-truth mixing snapshot (sent every `monitor_every` samples) —
    /// simulation-only side channel for the monitor.
    Mixing(Mat64),
    /// Stream exhausted.
    End,
}

/// Samples per producer block (amortizes channel + allocation overhead;
/// bounded so backpressure stays responsive).
pub(crate) const PRODUCER_BLOCK: usize = 256;

/// Channel capacity in producer blocks for a capacity expressed in samples.
pub(crate) fn block_capacity(samples: usize) -> usize {
    samples.max(1).div_ceil(PRODUCER_BLOCK).max(1)
}

/// Samples/sec that is safe against zero-duration windows: a run that
/// finishes inside one timer tick reports 0 rather than an inf/NaN (or
/// absurd 10¹²-scale) rate in the rendered tables.
pub(crate) fn safe_rate(count: u64, secs: f64) -> f64 {
    if secs.is_finite() && secs > 0.0 {
        count as f64 / secs
    } else {
        0.0
    }
}

/// Drain `total` samples out of `stream` as [`StreamEvent`]s: an initial
/// mixing snapshot, `PRODUCER_BLOCK`-row batches, a mixing snapshot every
/// `monitor_every` samples, and a final `End`. `emit` returns `false` to
/// abort (consumer hung up). This is the producer half of both the
/// single-stream server and every hub session.
pub(crate) fn drive_stream(
    stream: &mut MixedStream,
    total: usize,
    monitor_every: usize,
    emit: &mut dyn FnMut(StreamEvent) -> bool,
) {
    drive_stream_from(stream, total, monitor_every, 0, emit)
}

/// [`drive_stream`] with replay: the event schedule is a deterministic
/// function of `(total, monitor_every)`, so a restored session's producer
/// re-runs the identical schedule from the stream's seed and suppresses
/// the first `skip_events` events — the ones the consumer already applied
/// before it was detached to disk (its parked `consumed_upto` sequence
/// number; routed events are numbered from 1). Suppressed batches still
/// advance the stream sample-by-sample so the RNG state, mixing clock,
/// and every later event are bit-identical to the uninterrupted run.
/// `End` is always emitted.
pub(crate) fn drive_stream_from(
    stream: &mut MixedStream,
    total: usize,
    monitor_every: usize,
    skip_events: u64,
    emit: &mut dyn FnMut(StreamEvent) -> bool,
) {
    let m = stream.m();
    let monitor_every = monitor_every.max(1);
    let mut x = vec![0.0; m];
    let mut idx: u64 = 0;
    // Initial mixing snapshot so the monitor can evaluate early.
    idx += 1;
    if idx > skip_events && !emit(StreamEvent::Mixing(stream.current_mixing())) {
        return;
    }
    let mut produced = 0usize;
    let mut next_monitor = monitor_every;
    while produced < total {
        let rows = PRODUCER_BLOCK.min(total - produced);
        idx += 1;
        if idx > skip_events {
            let mut block = Mat64::zeros(rows, m);
            for r in 0..rows {
                stream.next_into(&mut x, None);
                block.row_mut(r).copy_from_slice(&x);
            }
            produced += rows;
            if !emit(StreamEvent::Batch(block)) {
                return;
            }
        } else {
            // Replayed prefix: advance the stream without materializing
            // or sending the block.
            for _ in 0..rows {
                stream.next_into(&mut x, None);
            }
            produced += rows;
        }
        if produced >= next_monitor {
            next_monitor += monitor_every;
            idx += 1;
            if idx > skip_events && !emit(StreamEvent::Mixing(stream.current_mixing())) {
                return;
            }
        }
    }
    let _ = emit(StreamEvent::End);
}

/// Server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServerOptions {
    /// Bounded-channel capacity (samples) — the backpressure depth.
    pub channel_capacity: usize,
    /// Send a mixing snapshot (and record a monitor point) every this
    /// many samples.
    pub monitor_every: usize,
    /// Convergence criterion for the monitor.
    pub criterion: ConvergenceCriterion,
    /// Automatic gain control time constant (samples). The front-end
    /// normalizes input power to ~1 before the separator — exactly what a
    /// hardware deployment's input scaling does, and what keeps the cubic
    /// nonlinearity's y⁴ terms bounded when the mixing switches abruptly.
    /// 0 disables AGC.
    pub agc_time_constant: usize,
    /// Divergence guard: if any element of B exceeds this after a chunk,
    /// the separator is reset to the warm start and the monitor re-armed
    /// (the divergence-recovery behaviour of classical adaptive filters).
    /// `f64::INFINITY` disables the guard (the non-finite check stays on).
    pub divergence_bound: f64,
    /// Numeric-fault retry budget: consecutive divergence-guard trips a
    /// session may accumulate (each one is a rollback-from-checkpoint or
    /// warm-start retry) before it latches a fault and is quarantined by
    /// its hosting worker. A clean chunk refills the budget.
    pub max_fault_retries: u64,
    /// Fixed-point divergence guard: saturation-latch events a q16/q32
    /// tenant may record in a single chunk before the divergence-recovery
    /// protocol trips (Q-format values are never NaN, so the non-finite
    /// check cannot fire for them — rail clamps are their blow-up
    /// signal). Healthy unit-power streams record none; a poisoned or
    /// railing stream records hundreds per chunk. `u64::MAX` disables the
    /// guard. Float tenants never record events, so this is inert for
    /// them.
    pub saturation_bound: u64,
}

impl Default for ServerOptions {
    fn default() -> Self {
        Self {
            channel_capacity: 4096,
            monitor_every: 256,
            criterion: ConvergenceCriterion::default(),
            agc_time_constant: 2048,
            divergence_bound: 1e4,
            max_fault_retries: 3,
            saturation_bound: 128,
        }
    }
}

/// Streaming automatic gain control: tracks an EMA of per-channel-average
/// sample power and scales samples to unit average power.
pub(crate) struct Agc {
    ema_power: f64,
    alpha: f64,
    primed: bool,
}

impl Agc {
    pub(crate) fn new(time_constant: usize) -> Self {
        Self {
            ema_power: 1.0,
            alpha: if time_constant == 0 { 0.0 } else { 1.0 / time_constant as f64 },
            primed: false,
        }
    }

    /// Normalize `x` in place; returns the gain applied.
    pub(crate) fn apply(&mut self, x: &mut [f64]) -> f64 {
        if self.alpha == 0.0 {
            return 1.0;
        }
        let p = x.iter().map(|v| v * v).sum::<f64>() / x.len() as f64;
        if !p.is_finite() {
            // A non-finite sample must not poison the gain tracker
            // forever: keep the EMA at its last healthy value and let the
            // divergence guard downstream deal with the poisoned chunk,
            // so a tenant whose input glitches NaN can still recover.
            return 1.0;
        }
        if !self.primed {
            // Prime with the first sample so startup isn't a huge step.
            self.ema_power = p.max(1e-12);
            self.primed = true;
        } else {
            self.ema_power += self.alpha * (p - self.ema_power);
        }
        let gain = 1.0 / self.ema_power.max(1e-12).sqrt();
        x.iter_mut().for_each(|v| *v *= gain);
        gain
    }

    /// Serialize the gain state (detach-to-disk; `alpha` is
    /// config-derived at rebuild time).
    pub(crate) fn save_state(&self, w: &mut crate::snapshot::SnapWriter) {
        w.put_f64(self.ema_power);
        w.put_bool(self.primed);
    }

    /// Rehydrate the state written by [`save_state`](Self::save_state).
    pub(crate) fn load_state(&mut self, r: &mut crate::snapshot::SnapReader<'_>) -> Result<()> {
        self.ema_power = r.get_f64()?;
        self.primed = r.get_bool()?;
        Ok(())
    }
}

/// Outcome of a streaming run.
#[derive(Clone, Debug)]
pub struct RunSummary {
    /// Samples actually applied to the separator.
    pub samples: u64,
    /// Samples dropped as a partial tail chunk (PJRT fixed shapes).
    pub tail_dropped: u64,
    pub elapsed_secs: f64,
    /// Applied samples per second (the software MIPS analogue).
    pub throughput_sps: f64,
    pub engine: String,
    pub final_amari: f64,
    pub converged_at: Option<u64>,
    /// Times the divergence guard reset the separator.
    pub resets: u64,
    /// Drift events the adaptive control plane detected (0 with
    /// `adapt.enabled = false`).
    pub drift_events: u64,
    /// Divergence recoveries served from the adaptive checkpoint instead
    /// of the warm start (subset of `resets`).
    pub rollbacks: u64,
    pub amari_history: Vec<MonitorPoint>,
    /// Final separation matrix.
    pub b: Mat64,
}

/// Build the `MixedStream` described by an experiment config.
pub fn build_stream(cfg: &ExperimentConfig) -> Result<MixedStream> {
    let mut rng = Pcg32::seed(cfg.seed);
    let bank = match cfg.signal.bank.as_str() {
        "sub_gaussian" => SourceBank::sub_gaussian(cfg.n),
        "eeg" => SourceBank::eeg_like(cfg.n),
        other => bail!("unknown signal.bank '{other}'"),
    };
    let mixing: Box<dyn crate::signal::MixingModel> = match cfg.signal.mixing.as_str() {
        "static" => Box::new(StaticMixing::random(&mut rng, cfg.m, cfg.n, cfg.signal.max_cond)),
        "rotating" => Box::new(RotatingMixing::random(
            &mut rng,
            cfg.m,
            cfg.n,
            cfg.signal.max_cond,
            cfg.signal.omega,
        )),
        "switching" => Box::new(SwitchingMixing::new(
            cfg.m,
            cfg.n,
            cfg.signal.period,
            cfg.signal.max_cond,
            cfg.seed ^ 0x5717_C41F,
        )),
        "switch_once" => Box::new(SwitchOnceMixing::random(
            &mut rng,
            cfg.m,
            cfg.n,
            cfg.signal.max_cond,
            cfg.signal.switch_at,
        )),
        "drift_onset" => Box::new(DriftOnsetMixing::random(
            &mut rng,
            cfg.m,
            cfg.n,
            cfg.signal.max_cond,
            cfg.signal.omega,
            cfg.signal.switch_at,
        )),
        "nan_burst" => Box::new(NanBurstMixing::random(
            &mut rng,
            cfg.m,
            cfg.n,
            cfg.signal.max_cond,
            cfg.signal.switch_at,
        )),
        other => bail!("unknown signal.mixing '{other}'"),
    };
    Ok(MixedStream::new(bank, mixing, rng))
}

/// The consumer half of one separation session: engine + chunker + AGC +
/// divergence guard + monitor + state publication, fed by [`StreamEvent`]s.
///
/// Extracted from the single-stream server so the multi-session hub
/// (`hub.rs`) can run many of these on a pool of worker shards. A session's
/// evolution depends only on its own event sequence, so a session behaves
/// bit-identically whether it runs here or multiplexed on a shard.
pub struct SessionRunner {
    engine: Box<dyn Engine>,
    chunker: Chunker,
    monitor: Monitor,
    agc: Agc,
    state: StateStore,
    current_a: Mat64,
    have_a: bool,
    warm_start: Mat64,
    divergence_bound: f64,
    resets: u64,
    /// The adaptive control plane (per session, `adapt.enabled`): drift
    /// detection on the separated outputs + μ governor + rollback
    /// checkpoint. `None` leaves the session bit-identical to the
    /// fixed-μ coordinator.
    adapt: Option<AdaptiveController>,
    /// Live health record this runner publishes into once per engine
    /// chunk — 64 samples at the defaults, i.e. at least as often as the
    /// monitor records — carrying phase, samples, last Amari,
    /// drift/rollback/reset counters and queue depth. The serving plane
    /// registers the same cell in the
    /// [`super::state::StateDirectory`]; a solo run publishes into a
    /// private, unregistered cell. Observational only — never read on
    /// the update path, so it cannot perturb the math.
    status: StatusCell,
    /// Shard ingest backlog observed when this session's latest block was
    /// dequeued (set by the hub worker, folded into the next status
    /// publish).
    observed_depth: usize,
    /// Latched at the first ingested event so a session's elapsed/sps
    /// measure its own service window, not hub setup time.
    started: Option<Instant>,
    /// Consecutive divergence-guard trips (a clean chunk resets it).
    /// Transient — deliberately not serialized: a restored session gets
    /// a fresh retry budget.
    fault_strikes: u64,
    /// Strike budget before a fault latches (from [`ServerOptions`]).
    max_fault_retries: u64,
    /// Per-chunk saturation-event budget (from [`ServerOptions`]).
    saturation_bound: u64,
    /// Engine saturation count at the previous chunk boundary, for the
    /// per-chunk delta. Transient telemetry — not serialized; a restored
    /// session's latch starts fresh.
    last_sat: u64,
    /// Latched numeric-fault reason. Once set, the hosting worker pulls
    /// this tenant off its shard (quarantine) instead of streaming
    /// garbage. Transient — not serialized.
    fault: Option<String>,
}

impl SessionRunner {
    pub fn new(
        cfg: &ExperimentConfig,
        engine: Box<dyn Engine>,
        options: &ServerOptions,
        state: StateStore,
    ) -> Self {
        let chunker = Chunker::new(cfg.m, engine.chunk_size());
        let adapt = cfg
            .adapt
            .enabled
            .then(|| AdaptiveController::new(&cfg.adapt, cfg.optimizer.mu, cfg.n, cfg.m));
        Self {
            chunker,
            monitor: Monitor::new(options.criterion),
            agc: Agc::new(options.agc_time_constant),
            state,
            current_a: Mat64::zeros(cfg.m, cfg.n),
            have_a: false,
            warm_start: crate::ica::init_b(cfg.n, cfg.m),
            divergence_bound: options.divergence_bound,
            resets: 0,
            adapt,
            status: StatusCell::new(0, &cfg.name),
            observed_depth: 0,
            started: None,
            fault_strikes: 0,
            max_fault_retries: options.max_fault_retries,
            saturation_bound: options.saturation_bound,
            last_sat: 0,
            fault: None,
            engine,
        }
    }

    /// The latched numeric-fault reason, if this session's divergence
    /// guard tripped more than `max_fault_retries` consecutive times —
    /// the hosting worker's signal to quarantine the tenant.
    pub fn fault(&self) -> Option<&str> {
        self.fault.as_deref()
    }

    /// Publish health into `cell` instead of the private default (the
    /// serving plane passes the directory-registered cell).
    pub fn set_status_cell(&mut self, cell: StatusCell) {
        self.status = cell;
    }

    /// The health cell this runner publishes into.
    pub fn status_cell(&self) -> StatusCell {
        self.status.clone()
    }

    /// Record the shard backlog seen when this session's latest block was
    /// dequeued; folded into the next status publish.
    pub(crate) fn note_queue_depth(&mut self, depth: usize) {
        self.observed_depth = depth;
    }

    /// Install a checkpointed separation matrix (the command plane's
    /// `restore` op) and publish it, re-arming convergence detection —
    /// the restored separator starts a fresh convergence story.
    pub fn install_b(&mut self, b: Mat64) {
        self.engine.reset_b(b);
        self.monitor.rearm();
        self.state.publish(self.engine.b(), self.engine.samples_done());
    }

    /// Start the service clock on the first ingested event.
    fn touch(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    /// Record a ground-truth mixing snapshot for the monitor.
    pub fn on_mixing(&mut self, a: Mat64) {
        self.touch();
        self.current_a = a;
        self.have_a = true;
    }

    /// Ingest one producer block: AGC-normalize, chunk, and apply through
    /// the engine, publishing state and monitoring after every chunk.
    pub fn on_block(&mut self, mut block: Mat64) -> Result<()> {
        self.touch();
        for r in 0..block.rows() {
            self.agc.apply(block.row_mut(r));
        }
        let Self {
            engine,
            chunker,
            monitor,
            state,
            current_a,
            have_a,
            warm_start,
            divergence_bound,
            resets,
            adapt,
            status,
            observed_depth,
            fault_strikes,
            max_fault_retries,
            saturation_bound,
            last_sat,
            fault,
            ..
        } = self;
        chunker
            .push_block(&block, |chunk| -> Result<()> {
                engine.submit_chunk(chunk)?;
                chunk_bookkeeping(
                    engine.as_mut(),
                    chunk,
                    monitor,
                    state,
                    current_a,
                    *have_a,
                    warm_start,
                    *divergence_bound,
                    resets,
                    adapt,
                    status,
                    *observed_depth,
                    fault_strikes,
                    *max_fault_retries,
                    *saturation_bound,
                    last_sat,
                    fault,
                );
                Ok(())
            })
            .map_err(|e| {
                // Surface the Chunker's re-entrancy contract in the error:
                // rows `0..consumed` of this block are ingested, the rest
                // never reached the chunker (see Chunker::push_block).
                e.error
                    .context(format!("block ingest failed with {} rows consumed", e.consumed))
            })
    }

    /// Cohort ingest, phase 1 (AGC + chunking only): normalize the block
    /// in place exactly like [`on_block`](Self::on_block), push its rows
    /// through the chunker, and append each completed chunk to `out`; a
    /// partial tail stays buffered, as on the per-session path. The
    /// engine is *not* touched — the cohort executor steps it later and
    /// then reports each chunk via
    /// [`note_cohort_chunk`](Self::note_cohort_chunk).
    pub(crate) fn ingest_block_into(&mut self, mut block: Mat64, out: &mut Vec<Mat64>) {
        self.touch();
        for r in 0..block.rows() {
            self.agc.apply(block.row_mut(r));
        }
        for r in 0..block.rows() {
            if let Some(chunk) = self.chunker.push(block.row(r)) {
                out.push(chunk);
            }
        }
    }

    /// Cohort ingest, phase 3: per-chunk bookkeeping after a cohort
    /// kernel advanced this session's engine (via
    /// [`cohort_sync`](Self::cohort_sync)) through exactly `chunk`.
    /// Runs the identical divergence-guard / control-plane / publication
    /// sequence the per-session path runs after `submit_chunk`, so the
    /// session's observable trajectory is the same either way.
    pub(crate) fn note_cohort_chunk(&mut self, chunk: &Mat64) {
        let Self {
            engine,
            monitor,
            state,
            current_a,
            have_a,
            warm_start,
            divergence_bound,
            resets,
            adapt,
            status,
            observed_depth,
            fault_strikes,
            max_fault_retries,
            saturation_bound,
            last_sat,
            fault,
            ..
        } = self;
        chunk_bookkeeping(
            engine.as_mut(),
            chunk,
            monitor,
            state,
            current_a,
            *have_a,
            warm_start,
            *divergence_bound,
            resets,
            adapt,
            status,
            *observed_depth,
            fault_strikes,
            *max_fault_retries,
            *saturation_bound,
            last_sat,
            fault,
        );
    }

    /// Apply one already-AGC'd, already-cut chunk through the engine with
    /// full bookkeeping — the cohort executor's flush path for chunks
    /// still queued when a lane leaves its pool (park, detach, End,
    /// cohort dissolving to a single member). Bit-identical to the same
    /// chunk's delivery inside [`on_block`](Self::on_block).
    pub(crate) fn apply_chunk(&mut self, chunk: &Mat64) -> Result<()> {
        let Self {
            engine,
            monitor,
            state,
            current_a,
            have_a,
            warm_start,
            divergence_bound,
            resets,
            adapt,
            status,
            observed_depth,
            fault_strikes,
            max_fault_retries,
            saturation_bound,
            last_sat,
            fault,
            ..
        } = self;
        engine.submit_chunk(chunk)?;
        chunk_bookkeeping(
            engine.as_mut(),
            chunk,
            monitor,
            state,
            current_a,
            *have_a,
            warm_start,
            *divergence_bound,
            resets,
            adapt,
            status,
            *observed_depth,
            fault_strikes,
            *max_fault_retries,
            *saturation_bound,
            last_sat,
            fault,
        );
        Ok(())
    }

    /// Cohort-execution probe, forwarded from the engine: `Some` iff this
    /// session can run as a cohort lane (plain fused EASI-SGD native
    /// engine), with its *current* μ.
    pub(crate) fn cohort_lane(&self) -> Option<CohortLane> {
        self.engine.cohort_lane()
    }

    /// Wire-format snapshot of the separation matrix for cohort loading.
    pub(crate) fn cohort_b(&self) -> Mat64 {
        self.engine.b()
    }

    /// Install the cohort-stepped B and account its consumed rows.
    pub(crate) fn cohort_sync(&mut self, b: &Mat64, rows: u64) {
        self.engine.cohort_sync(b, rows);
    }

    /// Wire-format snapshot of the SMBGD cross-batch accumulator (only
    /// meaningful on lanes whose [`cohort_lane`](Self::cohort_lane)
    /// reported the SMBGD form).
    pub(crate) fn cohort_hhat_prev(&self) -> Mat64 {
        self.engine.cohort_hhat_prev()
    }

    /// Install the SMBGD cohort step's `(B, Ĥ_prev)` and account its
    /// consumed rows / completed mini-batches.
    pub(crate) fn cohort_sync_smbgd(&mut self, b: &Mat64, hhat_prev: &Mat64, rows: u64) {
        self.engine.cohort_sync_smbgd(b, hhat_prev, rows);
    }

    /// Engine chunk size (part of the cohort shape key: lanes must cut
    /// chunks on identical boundaries to step in lockstep).
    pub(crate) fn chunk_size(&self) -> usize {
        self.engine.chunk_size()
    }

    /// Session shape `(n, m)`.
    pub(crate) fn shape(&self) -> (usize, usize) {
        self.warm_start.shape()
    }

    /// Cost-weighted placement load of this session, ≈ flops per engine
    /// chunk (`n × m × chunk`): the unit `LeastLoadedPlacement` balances,
    /// so a 64×64 tenant no longer weighs the same as a 2×2 one.
    pub fn placement_cost(&self) -> usize {
        let (n, m) = self.shape();
        (n * m * self.engine.chunk_size()).max(1)
    }

    /// Samples applied to the separator so far.
    pub fn samples_done(&self) -> u64 {
        self.engine.samples_done()
    }

    /// The state store this session publishes into.
    pub fn state(&self) -> &StateStore {
        &self.state
    }

    /// The adaptive controller, if this session runs the control plane.
    pub fn controller(&self) -> Option<&AdaptiveController> {
        self.adapt.as_ref()
    }

    /// Serialize everything a restarted process needs to continue this
    /// session bit-identically: engine (optimizer clocks + accumulators),
    /// chunker partial, monitor trajectory, AGC gain, ground-truth mixing
    /// cache, warm start, guard counters, adaptive control plane, and the
    /// published [`StateStore`] snapshot (so version numbering continues
    /// where it left off). The service clock and transient queue-depth
    /// observation restart fresh. Fails for engines without a state seam
    /// (PJRT).
    pub fn save_state(&self, w: &mut crate::snapshot::SnapWriter) -> Result<()> {
        self.engine.save_state(w)?;
        self.chunker.save_state(w);
        self.monitor.save_state(w);
        self.agc.save_state(w);
        w.put_mat64(&self.current_a);
        w.put_bool(self.have_a);
        w.put_mat64(&self.warm_start);
        w.put_u64(self.resets);
        w.put_bool(self.adapt.is_some());
        if let Some(ctrl) = &self.adapt {
            ctrl.save_state(w);
        }
        let snap = self.state.snapshot();
        w.put_u64(snap.version);
        w.put_u64(snap.samples);
        w.put_mat64(&snap.b);
        Ok(())
    }

    /// Rehydrate the state written by [`save_state`](Self::save_state)
    /// into a freshly constructed runner (same config, same options).
    /// Deliberately not [`install_b`](Self::install_b): a restore
    /// continues the old convergence story instead of re-arming it, and
    /// installs the engine's full optimizer state, not just B.
    pub fn load_state(&mut self, r: &mut crate::snapshot::SnapReader<'_>) -> Result<()> {
        self.engine.load_state(r)?;
        self.chunker.load_state(r)?;
        self.monitor.load_state(r)?;
        self.agc.load_state(r)?;
        let current_a = r.get_mat64()?;
        anyhow::ensure!(
            current_a.shape() == self.current_a.shape(),
            "snapshot mixing cache is {:?}, session expects {:?}",
            current_a.shape(),
            self.current_a.shape()
        );
        self.current_a = current_a;
        self.have_a = r.get_bool()?;
        let warm_start = r.get_mat64()?;
        anyhow::ensure!(
            warm_start.shape() == self.warm_start.shape(),
            "snapshot warm start is {:?}, session expects {:?}",
            warm_start.shape(),
            self.warm_start.shape()
        );
        self.warm_start = warm_start;
        self.resets = r.get_u64()?;
        let had_adapt = r.get_bool()?;
        anyhow::ensure!(
            had_adapt == self.adapt.is_some(),
            "snapshot was taken with the adaptive control plane {}, but this session has it {}",
            if had_adapt { "enabled" } else { "disabled" },
            if self.adapt.is_some() { "enabled" } else { "disabled" }
        );
        if let Some(ctrl) = self.adapt.as_mut() {
            ctrl.load_state(r)?;
        }
        let version = r.get_u64()?;
        let samples = r.get_u64()?;
        let b = r.get_mat64()?;
        self.state.restore(Snapshot { version, samples, b });
        Ok(())
    }

    /// Finalize: drop the partial tail chunk and assemble the summary.
    pub fn finish(mut self) -> RunSummary {
        let tail = self.chunker.take_partial().map(|t| t.rows() as u64).unwrap_or(0);
        let elapsed = self.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        let samples = self.engine.samples_done();
        let final_amari = if self.have_a {
            self.monitor.record(&self.engine.b(), &self.current_a, samples)
        } else {
            f64::NAN
        };
        self.status.publish_progress(
            samples,
            final_amari,
            self.resets,
            self.adapt.as_ref().map_or(0, |c| c.drift_events()),
            self.adapt.as_ref().map_or(0, |c| c.rollbacks()),
            self.observed_depth,
            self.engine.saturation_events(),
        );
        self.status.set_phase(SessionPhase::Drained);
        RunSummary {
            samples,
            tail_dropped: tail,
            elapsed_secs: elapsed,
            throughput_sps: safe_rate(samples, elapsed),
            engine: self.engine.describe(),
            final_amari,
            converged_at: self.monitor.converged_at(),
            resets: self.resets,
            drift_events: self.adapt.as_ref().map_or(0, |c| c.drift_events()),
            rollbacks: self.adapt.as_ref().map_or(0, |c| c.rollbacks()),
            amari_history: self.monitor.history().to_vec(),
            b: self.engine.b(),
        }
    }
}

/// Per-chunk tail of the ingest path, shared verbatim by the per-session
/// route (`on_block`/`apply_chunk`, right after `submit_chunk`) and the
/// cohort route (`note_cohort_chunk`, right after `cohort_sync`):
/// divergence guard, adaptive control plane, state publication,
/// monitoring, health publishing. A free function over the destructured
/// runner fields because `on_block` calls it while `push_block` holds the
/// chunker borrow.
#[allow(clippy::too_many_arguments)] // flat seam over SessionRunner's fields, see above
fn chunk_bookkeeping(
    engine: &mut dyn Engine,
    chunk: &Mat64,
    monitor: &mut Monitor,
    state: &mut StateStore,
    current_a: &Mat64,
    have_a: bool,
    warm_start: &Mat64,
    divergence_bound: f64,
    resets: &mut u64,
    adapt: &mut Option<AdaptiveController>,
    status: &mut StatusCell,
    observed_depth: usize,
    fault_strikes: &mut u64,
    max_fault_retries: u64,
    saturation_bound: u64,
    last_sat: &mut u64,
    fault: &mut Option<String>,
) {
    let b = engine.b();
    // Fixed-point divergence surveillance: the per-chunk delta of the
    // engine's saturation-latch counter. A Q-format separator can't go
    // non-finite — it rails — so a burst of rail clamps is its blow-up
    // signal, and it feeds the same recovery protocol below. Float
    // engines report a constant 0 and never trip this arm.
    let sat_total = engine.saturation_events();
    let sat_delta = sat_total.saturating_sub(*last_sat);
    *last_sat = sat_total;
    let saturated = sat_delta > saturation_bound;
    // Divergence guard: large-mu EASI under abrupt mixing
    // switches can blow up; recover like an adaptive filter.
    if !b.is_finite() || b.max_abs() > divergence_bound || saturated {
        // Rollback protocol: with the control plane active and a
        // steady-state checkpoint on hand, restore that (the last
        // known-good separator) instead of the cold warm start.
        // Either way the governor cools and the detector disarms —
        // re-applying a boosted μ to a freshly reset separator
        // would just diverge again, and the reset's whiteness
        // spike is not drift.
        let mut recovered = false;
        if let Some(ctrl) = adapt.as_mut() {
            if let Some(ck) = ctrl.rollback_b() {
                let ck = ck.clone();
                engine.reset_b(ck);
                recovered = true;
            }
            if recovered {
                ctrl.on_rollback();
            } else {
                ctrl.on_divergence_reset();
            }
            engine.set_mu(ctrl.mu(engine.samples_done()));
        }
        if !recovered {
            engine.reset_b(warm_start.clone());
        }
        monitor.rearm();
        *resets += 1;
        // Numeric-fault quarantine: every trip above *is* one retry of
        // the rollback/reset recovery. A separator that stays broken
        // for more than `max_fault_retries` consecutive chunks is not
        // recovering — its input stream is poisoned (NaN/Inf) or its
        // dynamics are unstable — so latch a fault for the hosting
        // worker to quarantine on, instead of resetting forever and
        // silently streaming garbage.
        *fault_strikes += 1;
        if *fault_strikes > max_fault_retries && fault.is_none() {
            let what = if saturated {
                "fixed-point saturation burst"
            } else {
                "non-finite or diverged separator"
            };
            *fault = Some(format!(
                "{} persisted through {} consecutive rollback/reset attempts",
                what, *fault_strikes
            ));
        }
    } else {
        // A clean chunk refills the numeric-fault retry budget: the
        // guard only quarantines *consecutive* failures.
        *fault_strikes = 0;
        if let Some(ctrl) = adapt.as_mut() {
            // Closed loop: observe the separated outputs of this
            // chunk (strided), detect drift, govern μ, and keep the
            // recovery checkpoint fresh while steady.
            let done = engine.samples_done();
            if ctrl.observe_chunk(&b, chunk, done).is_some() {
                // Re-arm convergence detection so the monitor reports
                // a post-drift `converged_at` instead of staying
                // latched on the pre-drift one.
                monitor.rearm();
            } else {
                ctrl.checkpoint_if_steady(&b);
            }
            engine.set_mu(ctrl.mu(done));
        }
    }
    state.publish(engine.b(), engine.samples_done());
    let amari = if have_a {
        monitor.record(&engine.b(), current_a, engine.samples_done())
    } else {
        f64::NAN
    };
    // Live health plane: one coherent record per engine chunk.
    // Pure observation — nothing on the update path reads it
    // back, so trajectories stay bit-identical.
    status.publish_progress(
        engine.samples_done(),
        amari,
        *resets,
        adapt.as_ref().map_or(0, |c| c.drift_events()),
        adapt.as_ref().map_or(0, |c| c.rollbacks()),
        observed_depth,
        sat_total,
    );
}

/// Run the full streaming pipeline: produce `cfg.samples` samples, apply
/// them through `engine`, monitor convergence against the simulation's
/// ground truth, and publish state into `state`.
///
/// Since the hub refactor this is a thin one-session wrapper: one producer
/// thread driving [`drive_stream`] into a bounded channel, one
/// [`SessionRunner`] consuming it on the caller's thread.
pub fn run_streaming(
    cfg: &ExperimentConfig,
    engine: Box<dyn Engine>,
    options: ServerOptions,
    state: &StateStore,
) -> Result<RunSummary> {
    let mut stream = build_stream(cfg)?;
    let total = cfg.samples;
    let monitor_every = options.monitor_every.max(1);

    // Channel capacity is expressed in samples; convert to blocks.
    let capacity = block_capacity(options.channel_capacity);
    let (tx, rx): (SyncSender<StreamEvent>, Receiver<StreamEvent>) = sync_channel(capacity);

    let producer = thread::spawn(move || {
        drive_stream(&mut stream, total, monitor_every, &mut |ev| tx.send(ev).is_ok());
    });

    let mut runner = SessionRunner::new(cfg, engine, &options, state.clone());
    loop {
        match rx.recv().context("producer channel closed unexpectedly")? {
            StreamEvent::Batch(block) => runner.on_block(block)?,
            StreamEvent::Mixing(a) => runner.on_mixing(a),
            StreamEvent::End => break,
        }
    }
    producer.join().ok();
    Ok(runner.finish())
}

/// Convenience: build engine + state and run, returning the summary.
pub fn run_experiment(cfg: &ExperimentConfig, g: Nonlinearity) -> Result<RunSummary> {
    let engine = super::engine::make_engine(cfg, g)?;
    let state = StateStore::new(crate::ica::init_b(cfg.n, cfg.m));
    run_streaming(cfg, engine, ServerOptions::default(), &state)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OptimizerKind;

    fn small_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.samples = 20_000;
        cfg.optimizer.mu = 0.004;
        cfg
    }

    #[test]
    fn native_smbgd_end_to_end_converges() {
        let cfg = small_cfg();
        let sum = run_experiment(&cfg, Nonlinearity::Cube).unwrap();
        assert_eq!(sum.samples + sum.tail_dropped, 20_000);
        assert!(sum.final_amari < 0.2, "final amari {}", sum.final_amari);
        assert!(sum.throughput_sps > 1000.0);
        assert!(!sum.amari_history.is_empty());
    }

    #[test]
    fn native_sgd_end_to_end() {
        let mut cfg = small_cfg();
        cfg.optimizer.kind = OptimizerKind::Sgd;
        let sum = run_experiment(&cfg, Nonlinearity::Cube).unwrap();
        assert!(sum.engine.contains("easi-sgd"));
        assert!(sum.final_amari < 0.3, "final amari {}", sum.final_amari);
    }

    #[test]
    fn state_store_sees_updates() {
        let cfg = small_cfg();
        let engine = super::super::engine::make_engine(&cfg, Nonlinearity::Cube).unwrap();
        let state = StateStore::new(crate::ica::init_b(cfg.n, cfg.m));
        let _ = run_streaming(&cfg, engine, ServerOptions::default(), &state).unwrap();
        assert!(state.version() > 10, "state should be published repeatedly");
        assert!(state.snapshot().samples > 0);
    }

    #[test]
    fn rotating_mixing_is_tracked() {
        let mut cfg = small_cfg();
        cfg.samples = 60_000;
        cfg.optimizer.mu = 0.008;
        cfg.signal.mixing = "rotating".into();
        cfg.signal.omega = 1e-5;
        let sum = run_experiment(&cfg, Nonlinearity::Cube).unwrap();
        // adaptive EASI should keep separating while A rotates
        assert!(sum.final_amari < 0.3, "tracking amari {}", sum.final_amari);
    }

    #[test]
    fn agc_normalizes_power() {
        let mut agc = Agc::new(64);
        let mut rng = crate::signal::Pcg32::seed(1);
        let mut mean_p = 0.0;
        let n_samples = 5000;
        for _ in 0..n_samples {
            // raw power ~ 25x unit
            let mut x = [rng.normal() * 5.0, rng.normal() * 5.0];
            agc.apply(&mut x);
            mean_p += (x[0] * x[0] + x[1] * x[1]) / 2.0 / n_samples as f64;
        }
        assert!((mean_p - 1.0).abs() < 0.1, "AGC output power {mean_p}");
    }

    #[test]
    fn agc_disabled_is_identity() {
        let mut agc = Agc::new(0);
        let mut x = [3.0, -4.0];
        let g = agc.apply(&mut x);
        assert_eq!(g, 1.0);
        assert_eq!(x, [3.0, -4.0]);
    }

    #[test]
    fn agc_adapts_to_scale_jump() {
        let mut agc = Agc::new(128);
        let mut x = [1.0, -1.0];
        agc.apply(&mut x);
        // jump input scale 100x; after ~10 time constants gain settles
        let mut last = [0.0, 0.0];
        for _ in 0..2000 {
            let mut x = [100.0, -100.0];
            agc.apply(&mut x);
            last = x;
        }
        let p = (last[0] * last[0] + last[1] * last[1]) / 2.0;
        assert!((p - 1.0).abs() < 0.1, "post-jump power {p}");
    }

    #[test]
    fn bad_bank_is_rejected() {
        let mut cfg = small_cfg();
        cfg.signal.bank = "nope".into();
        assert!(build_stream(&cfg).is_err());
    }

    #[test]
    fn repeated_divergence_latches_a_fault_and_clean_chunks_refill_the_budget() {
        let mut cfg = small_cfg();
        cfg.optimizer.kind = OptimizerKind::Sgd;
        let engine = super::super::engine::make_engine(&cfg, Nonlinearity::Cube).unwrap();
        let state = StateStore::new(crate::ica::init_b(cfg.n, cfg.m));
        let mut runner = SessionRunner::new(&cfg, engine, &ServerOptions::default(), state);
        let chunk = runner.chunk_size();
        let poison = |chunks: usize| Mat64::from_fn(chunks * chunk, cfg.m, |_, _| f64::NAN);
        let mut rng = Pcg32::seed(7);
        let mut clean = Mat64::zeros(chunk, cfg.m);
        for r in 0..chunk {
            for c in 0..cfg.m {
                clean[(r, c)] = rng.normal();
            }
        }

        // Two poisoned chunks: strikes accrue, but the default budget of
        // 3 retries is not exhausted.
        runner.on_block(poison(2)).unwrap();
        assert!(runner.fault().is_none(), "2 strikes sit within the retry budget");
        // A clean chunk refills the budget (and must not be poisoned by
        // the NaN prefix: the AGC guard keeps the gain tracker finite).
        runner.on_block(clean.clone()).unwrap();
        assert!(runner.fault().is_none());
        // Three more poisoned chunks: still within budget (counting
        // restarted at zero after the clean chunk)...
        runner.on_block(poison(3)).unwrap();
        assert!(runner.fault().is_none(), "budget was refilled by the clean chunk");
        // ...but the fourth consecutive failure latches the fault.
        runner.on_block(poison(1)).unwrap();
        let fault = runner.fault().expect("4 consecutive strikes exceed the budget");
        assert!(fault.contains("rollback/reset attempts"), "{fault}");
        // Latching is sticky and non-panicking: further blocks still flow.
        runner.on_block(clean).unwrap();
        assert!(runner.fault().is_some(), "a latched fault stays latched");
    }

    #[test]
    fn q16_saturation_burst_trips_the_guard_and_quarantines() {
        // The fixed-point analogue of the NaN-poisoning drill above: a
        // q16 separator can never go non-finite (NaN inputs quantize to
        // zero on the rails' lattice), so the saturation latch is what
        // feeds the divergence-recovery protocol and, persisted, the
        // quarantine fault.
        let mut cfg = small_cfg();
        cfg.optimizer.kind = OptimizerKind::Sgd;
        cfg.precision = crate::config::Precision::Q16;
        let engine = super::super::engine::make_engine(&cfg, Nonlinearity::Cube).unwrap();
        let state = StateStore::new(crate::ica::init_b(cfg.n, cfg.m));
        let mut runner = SessionRunner::new(&cfg, engine, &ServerOptions::default(), state);
        let chunk = runner.chunk_size();
        let poison = |chunks: usize| Mat64::from_fn(chunks * chunk, cfg.m, |_, _| f64::NAN);
        let mut rng = Pcg32::seed(9);
        let clean = Mat64::from_fn(chunk, cfg.m, |_, _| rng.normal());

        // A healthy chunk stays far under the per-chunk bound (Gaussian
        // tails may clip a handful of casts past ±2 — that is normal
        // q16 operation, not a burst): no strike, no reset.
        runner.on_block(clean.clone()).unwrap();
        assert!(runner.fault().is_none());
        let quiet = runner.status_cell().snapshot();
        assert_eq!(quiet.resets, 0, "healthy chunk must not trip the guard");
        assert!(quiet.saturations <= 64, "healthy stream is near-quiet: {}", quiet.saturations);
        // Poisoned chunks latch events well past the per-chunk bound
        // (one per NaN element at minimum), accruing strikes...
        runner.on_block(poison(2)).unwrap();
        assert!(runner.fault().is_none(), "2 strikes sit within the retry budget");
        let sat = runner.status_cell().snapshot().saturations;
        assert!(sat > 0, "saturation count must surface in the status record");
        // ...a clean chunk refills the budget...
        runner.on_block(clean.clone()).unwrap();
        assert!(runner.fault().is_none());
        // ...and four consecutive saturated chunks exceed it.
        runner.on_block(poison(4)).unwrap();
        let fault = runner.fault().expect("saturation burst must latch a fault");
        assert!(fault.contains("saturation"), "{fault}");
        // The cumulative count only grows; the fault is sticky.
        assert!(runner.status_cell().snapshot().saturations >= sat);
        runner.on_block(clean).unwrap();
        assert!(runner.fault().is_some());
    }

    #[test]
    fn safe_rate_guards_zero_duration() {
        assert_eq!(safe_rate(1000, 2.0), 500.0);
        assert_eq!(safe_rate(1000, 0.0), 0.0, "zero-duration run must not blow up");
        assert_eq!(safe_rate(1000, -1.0), 0.0);
        assert_eq!(safe_rate(1000, f64::NAN), 0.0);
        assert_eq!(safe_rate(0, 0.0), 0.0);
        assert!(safe_rate(u64::MAX, 1.0).is_finite());
    }

    #[test]
    fn switch_once_stream_builds_and_switches() {
        let mut cfg = small_cfg();
        cfg.signal.mixing = "switch_once".into();
        cfg.signal.switch_at = 100;
        let mut stream = build_stream(&cfg).unwrap();
        let a0 = stream.current_mixing();
        let mut x = vec![0.0; cfg.m];
        for _ in 0..150 {
            stream.next_into(&mut x, None);
        }
        assert!(stream.current_mixing().max_abs_diff(&a0) > 0.05);
        cfg.signal.mixing = "drift_onset".into();
        assert!(build_stream(&cfg).is_ok());
    }

    #[test]
    fn adaptive_session_detects_switch_and_reconverges() {
        // The closed loop end to end through the streaming coordinator:
        // a mixing switch mid-stream must be detected (drift_events ≥ 1)
        // and the monitor — re-armed by the control plane — must latch a
        // *post-switch* convergence.
        let mut cfg = ExperimentConfig::default();
        cfg.samples = 60_000;
        cfg.optimizer.kind = OptimizerKind::Sgd;
        cfg.optimizer.mu = 0.01;
        cfg.signal.mixing = "switch_once".into();
        cfg.signal.switch_at = 25_000;
        cfg.adapt.enabled = true;
        let sum = run_experiment(&cfg, Nonlinearity::Cube).unwrap();
        assert!(sum.drift_events >= 1, "switch not detected");
        assert!(sum.final_amari < 0.35, "post-switch amari {}", sum.final_amari);
        let conv = sum.converged_at.expect("monitor should re-latch after re-arm");
        assert!(
            conv > 25_000,
            "converged_at {conv} should postdate the switch (monitor re-armed)"
        );
    }

    #[test]
    fn drive_stream_from_replays_identical_suffix() {
        let cfg = small_cfg();
        let mut all = Vec::new();
        let mut s1 = build_stream(&cfg).unwrap();
        drive_stream(&mut s1, 2000, 256, &mut |ev| {
            all.push(ev);
            true
        });
        let skip = 3u64;
        let mut tail = Vec::new();
        let mut s2 = build_stream(&cfg).unwrap();
        drive_stream_from(&mut s2, 2000, 256, skip, &mut |ev| {
            tail.push(ev);
            true
        });
        assert_eq!(all.len(), tail.len() + skip as usize);
        for (a, b) in all.iter().skip(skip as usize).zip(&tail) {
            match (a, b) {
                (StreamEvent::Batch(x), StreamEvent::Batch(y)) => assert_eq!(x, y),
                (StreamEvent::Mixing(x), StreamEvent::Mixing(y)) => assert_eq!(x, y),
                (StreamEvent::End, StreamEvent::End) => {}
                _ => panic!("replayed event kind diverged"),
            }
        }
    }

    #[test]
    fn session_runner_snapshot_round_trip_is_bit_identical() {
        // The detach-to-disk contract at the runner level: save mid-stream,
        // rebuild a fresh runner from config, load, feed the remaining
        // events — the final B, sample count, and counters must be bitwise
        // those of the uninterrupted run.
        let mut cfg = small_cfg();
        cfg.samples = 8_000;
        cfg.adapt.enabled = true;
        let opts = ServerOptions::default();
        let reference = {
            let engine = super::super::engine::make_engine(&cfg, Nonlinearity::Cube).unwrap();
            let state = StateStore::new(crate::ica::init_b(cfg.n, cfg.m));
            run_streaming(&cfg, engine, opts, &state).unwrap()
        };

        let mut events = Vec::new();
        let mut stream = build_stream(&cfg).unwrap();
        drive_stream(&mut stream, cfg.samples, opts.monitor_every, &mut |ev| {
            events.push(ev);
            true
        });
        let cut = events.len() / 2;
        let engine = super::super::engine::make_engine(&cfg, Nonlinearity::Cube).unwrap();
        let mut runner = SessionRunner::new(
            &cfg,
            engine,
            &opts,
            StateStore::new(crate::ica::init_b(cfg.n, cfg.m)),
        );
        let mut iter = events.into_iter();
        for ev in iter.by_ref().take(cut) {
            match ev {
                StreamEvent::Batch(b) => runner.on_block(b).unwrap(),
                StreamEvent::Mixing(a) => runner.on_mixing(a),
                StreamEvent::End => {}
            }
        }
        let mut w = crate::snapshot::SnapWriter::new();
        runner.save_state(&mut w).unwrap();
        let payload = w.into_payload();
        let cut_version = runner.state().version();
        drop(runner);

        let engine = super::super::engine::make_engine(&cfg, Nonlinearity::Cube).unwrap();
        let mut restored = SessionRunner::new(
            &cfg,
            engine,
            &opts,
            StateStore::new(crate::ica::init_b(cfg.n, cfg.m)),
        );
        let mut r = crate::snapshot::SnapReader::from_payload(&payload);
        restored.load_state(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(restored.state().version(), cut_version, "version continuity");
        for ev in iter {
            match ev {
                StreamEvent::Batch(b) => restored.on_block(b).unwrap(),
                StreamEvent::Mixing(a) => restored.on_mixing(a),
                StreamEvent::End => {}
            }
        }
        let sum = restored.finish();
        assert_eq!(sum.b, reference.b, "restored trajectory diverged");
        assert_eq!(sum.samples, reference.samples);
        assert_eq!(sum.resets, reference.resets);
        assert_eq!(sum.drift_events, reference.drift_events);
        assert_eq!(sum.converged_at, reference.converged_at);
        assert_eq!(sum.amari_history, reference.amari_history);
    }

    #[test]
    fn disabled_adapt_knobs_do_not_touch_the_pipeline() {
        // With adapt.enabled = false no controller is built, so the
        // adapt.* tuning knobs must have exactly zero effect on the run —
        // wildly different knob values, bit-identical B. (This is the
        // observable form of "a disabled session is the PR-3 fixed-μ
        // coordinator": any control-plane code leaking onto the disabled
        // path would move B.)
        let cfg = small_cfg();
        let mut tuned = cfg.clone();
        tuned.adapt.stride = 1;
        tuned.adapt.alpha = 0.5;
        tuned.adapt.boost = 9.0;
        tuned.adapt.tau = 10.0;
        tuned.adapt.rollback = false;
        assert!(!tuned.adapt.enabled, "small_cfg must leave adapt off");
        let a = run_experiment(&cfg, Nonlinearity::Cube).unwrap();
        let b = run_experiment(&tuned, Nonlinearity::Cube).unwrap();
        assert_eq!(a.b, b.b);
        assert_eq!(a.drift_events, 0);
        assert_eq!(a.rollbacks, 0);
    }
}
