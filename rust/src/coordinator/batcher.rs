//! Chunker: groups per-sample events into fixed-size row-major chunks for
//! the engines — the software analogue of the paper's "one sample per
//! clock into the pipeline" ingestion, with the chunk boundary playing the
//! role of the mini-batch boundary.

use crate::linalg::Mat64;

/// Error from [`Chunker::push_block`]: the `on_chunk` error plus exactly
/// how many rows of the submitted block the chunker consumed before it
/// fired, so callers can resume without double-ingesting (see the method
/// docs for the full contract).
#[derive(Debug, PartialEq, Eq)]
pub struct BlockError<E> {
    /// Rows of the failing block consumed (`0..consumed` must not be
    /// resubmitted; `consumed..` were untouched).
    pub consumed: usize,
    /// The underlying `on_chunk` error.
    pub error: E,
}

/// Accumulates samples (rows) until a full `chunk × m` matrix is ready.
pub struct Chunker {
    m: usize,
    chunk: usize,
    buf: Vec<f64>,
    rows: usize,
    total: u64,
}

impl Chunker {
    pub fn new(m: usize, chunk: usize) -> Self {
        assert!(m >= 1 && chunk >= 1);
        Self { m, chunk, buf: Vec::with_capacity(m * chunk), rows: 0, total: 0 }
    }

    /// Push one sample; returns a full chunk when ready.
    pub fn push(&mut self, x: &[f64]) -> Option<Mat64> {
        assert_eq!(x.len(), self.m, "sample dimensionality mismatch");
        self.buf.extend_from_slice(x);
        self.rows += 1;
        self.total += 1;
        if self.rows == self.chunk {
            let mat = Mat64::from_slice(self.chunk, self.m, &self.buf);
            self.buf.clear();
            self.rows = 0;
            Some(mat)
        } else {
            None
        }
    }

    /// Push every row of a block, invoking `on_chunk` for each completed
    /// chunk. This is the hub/server ingest path: one call per producer
    /// block instead of one `Option` check per sample at the call site.
    /// Stops at the first error.
    ///
    /// **Error contract** (the ingest path's re-entrancy seam): on
    /// `Err(BlockError { consumed, error })`,
    ///
    /// - rows `0..consumed` of *this block* have been consumed by the
    ///   chunker — counted in [`total_pushed`](Self::total_pushed) and
    ///   either emitted inside a chunk or still buffered as a partial.
    ///   Re-pushing any of them double-ingests samples.
    /// - the last emitted chunk is the one `on_chunk` failed on; it was
    ///   delivered exactly once (its final row is `block[consumed - 1]`).
    ///   Whether its samples reached the sink is the caller's contract
    ///   with `on_chunk` — a transactional sink may retry the delivery
    ///   with the chunk it already holds, never through the chunker.
    /// - rows `consumed..` were not touched; resume by pushing exactly
    ///   those (see `push_block_error_is_resumable` below).
    pub fn push_block<E>(
        &mut self,
        block: &Mat64,
        mut on_chunk: impl FnMut(&Mat64) -> Result<(), E>,
    ) -> Result<(), BlockError<E>> {
        for r in 0..block.rows() {
            if let Some(chunk) = self.push(block.row(r)) {
                if let Err(error) = on_chunk(&chunk) {
                    return Err(BlockError { consumed: r + 1, error });
                }
            }
        }
        Ok(())
    }

    /// Samples currently buffered (not yet emitted).
    pub fn pending(&self) -> usize {
        self.rows
    }

    /// Total samples pushed over the lifetime.
    pub fn total_pushed(&self) -> u64 {
        self.total
    }

    /// Drain the partial tail (fewer than `chunk` rows), if any.
    ///
    /// The PJRT engine cannot run partial chunks (fixed-shape programs);
    /// the server either drops the tail (recording it in the summary) or
    /// routes it to a native fallback.
    pub fn take_partial(&mut self) -> Option<Mat64> {
        if self.rows == 0 {
            return None;
        }
        let mat = Mat64::from_slice(self.rows, self.m, &self.buf);
        self.buf.clear();
        self.rows = 0;
        Some(mat)
    }

    /// Serialize the lifetime counter and the buffered partial chunk
    /// (detach-to-disk; `m`/`chunk` are config-derived at rebuild time).
    pub fn save_state(&self, w: &mut crate::snapshot::SnapWriter) {
        w.put_u64(self.total);
        w.put_f64_slice(&self.buf);
    }

    /// Rehydrate the state written by [`save_state`](Self::save_state).
    pub fn load_state(&mut self, r: &mut crate::snapshot::SnapReader<'_>) -> anyhow::Result<()> {
        let total = r.get_u64()?;
        let buf = r.get_f64_vec()?;
        anyhow::ensure!(
            buf.len() % self.m == 0,
            "snapshot partial chunk holds {} value(s), not a multiple of m = {}",
            buf.len(),
            self.m
        );
        let rows = buf.len() / self.m;
        anyhow::ensure!(
            rows < self.chunk,
            "snapshot partial chunk has {rows} row(s), but a full chunk is {}",
            self.chunk
        );
        self.buf = buf;
        self.rows = rows;
        self.total = total;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_every_chunk() {
        let mut ch = Chunker::new(2, 3);
        assert!(ch.push(&[1.0, 2.0]).is_none());
        assert!(ch.push(&[3.0, 4.0]).is_none());
        let full = ch.push(&[5.0, 6.0]).expect("full chunk");
        assert_eq!(full.shape(), (3, 2));
        assert_eq!(full[(2, 1)], 6.0);
        assert_eq!(ch.pending(), 0);
    }

    #[test]
    fn preserves_order() {
        let mut ch = Chunker::new(1, 4);
        for i in 0..3 {
            assert!(ch.push(&[i as f64]).is_none());
        }
        let full = ch.push(&[3.0]).unwrap();
        assert_eq!(full.as_slice(), &[0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn partial_tail() {
        let mut ch = Chunker::new(2, 4);
        ch.push(&[1.0, 2.0]);
        ch.push(&[3.0, 4.0]);
        let tail = ch.take_partial().unwrap();
        assert_eq!(tail.shape(), (2, 2));
        assert!(ch.take_partial().is_none());
        assert_eq!(ch.total_pushed(), 2);
    }

    #[test]
    fn counts_across_chunks() {
        let mut ch = Chunker::new(1, 2);
        let mut chunks = 0;
        for i in 0..10 {
            if ch.push(&[i as f64]).is_some() {
                chunks += 1;
            }
        }
        assert_eq!(chunks, 5);
        assert_eq!(ch.total_pushed(), 10);
    }

    #[test]
    fn push_block_emits_chunks_in_order() {
        let mut ch = Chunker::new(1, 2);
        let block = Mat64::from_fn(5, 1, |i, _| i as f64);
        let mut seen = Vec::new();
        ch.push_block(&block, |chunk| -> Result<(), ()> {
            seen.extend_from_slice(chunk.as_slice());
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(ch.pending(), 1, "5th row stays buffered");
    }

    #[test]
    fn push_block_stops_on_error() {
        let mut ch = Chunker::new(1, 1);
        let block = Mat64::from_fn(4, 1, |i, _| i as f64);
        let mut calls = 0;
        let res = ch.push_block(&block, |_| {
            calls += 1;
            if calls == 2 {
                Err("boom")
            } else {
                Ok(())
            }
        });
        assert_eq!(res, Err(BlockError { consumed: 2, error: "boom" }));
        assert_eq!(calls, 2, "chunks after the error must not be emitted");
        assert_eq!(ch.total_pushed(), 2, "rows after the error must not be consumed");
        assert_eq!(ch.pending(), 0);
    }

    #[test]
    fn push_block_error_reports_consumed_through_failing_chunk() {
        // chunk = 2 against a 7-row block, failing on the second chunk
        // (block rows 2..4): consumed must cover the failing chunk's last
        // row, rows 4.. must stay untouched, and a partial from *before*
        // the block must be accounted inside `consumed`'s row arithmetic.
        let mut ch = Chunker::new(1, 2);
        ch.push(&[-1.0]); // pre-existing partial: first chunk is [-1, 0]
        let block = Mat64::from_fn(7, 1, |i, _| i as f64);
        let mut chunks = 0;
        let err = ch
            .push_block(&block, |_| {
                chunks += 1;
                if chunks == 2 {
                    Err("sink full")
                } else {
                    Ok(())
                }
            })
            .unwrap_err();
        // Chunk 1 completed at block row 0, chunk 2 at block row 2.
        assert_eq!(err, BlockError { consumed: 3, error: "sink full" });
        assert_eq!(ch.total_pushed(), 4, "1 pre-existing + 3 block rows");
        assert_eq!(ch.pending(), 0, "failing chunk drained the buffer");
    }

    #[test]
    fn push_block_error_is_resumable() {
        // The regression the contract exists for: after a transient sink
        // error, a caller that resumes from `consumed` (retrying the
        // failed delivery with the chunk it already holds) ingests every
        // sample exactly once — no loss, no double ingestion.
        let mut ch = Chunker::new(1, 2);
        let block = Mat64::from_fn(6, 1, |i, _| i as f64);
        let mut sink = Vec::new();
        let mut failed = None;
        let err = ch
            .push_block(&block, |c| {
                if sink.len() == 2 && failed.is_none() {
                    // Transactional sink: reject the chunk untouched.
                    failed = Some(c.clone());
                    return Err("transient");
                }
                sink.extend_from_slice(c.as_slice());
                Ok(())
            })
            .unwrap_err();
        assert_eq!(err.consumed, 4);
        // Caller-side recovery: redeliver the rejected chunk, then push
        // only the untouched remainder through the chunker.
        sink.extend_from_slice(failed.unwrap().as_slice());
        for r in err.consumed..block.rows() {
            if let Some(c) = ch.push(block.row(r)) {
                sink.extend_from_slice(c.as_slice());
            }
        }
        assert_eq!(sink, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(ch.total_pushed(), 6);
        assert_eq!(ch.pending(), 0);
    }

    #[test]
    #[should_panic(expected = "dimensionality")]
    fn wrong_dim_panics() {
        let mut ch = Chunker::new(3, 2);
        ch.push(&[1.0]);
    }

    #[test]
    fn partial_flush_at_stream_end_via_push_block() {
        // The server's end-of-stream path: a block leaves a partial chunk
        // buffered; take_partial drains exactly those rows, in order, and
        // the chunker is reusable afterwards.
        let mut ch = Chunker::new(2, 4);
        let block = Mat64::from_fn(6, 2, |i, j| (2 * i + j) as f64);
        let mut chunks = 0;
        ch.push_block(&block, |c| -> Result<(), ()> {
            assert_eq!(c.shape(), (4, 2));
            chunks += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(chunks, 1);
        assert_eq!(ch.pending(), 2);
        let tail = ch.take_partial().expect("partial tail");
        assert_eq!(tail.shape(), (2, 2));
        assert_eq!(tail.as_slice(), &[8.0, 9.0, 10.0, 11.0]);
        assert_eq!(ch.pending(), 0);
        assert!(ch.take_partial().is_none(), "double drain must be empty");
        // Reusable: the next pushes start a fresh chunk.
        assert!(ch.push(&[0.0, 0.0]).is_none());
        assert_eq!(ch.pending(), 1);
        assert_eq!(ch.total_pushed(), 7);
    }

    #[test]
    fn chunk_size_one_emits_every_sample() {
        let mut ch = Chunker::new(3, 1);
        for i in 0..5 {
            let x = [i as f64, 0.0, 0.0];
            let chunk = ch.push(&x).expect("chunk size 1 emits per push");
            assert_eq!(chunk.shape(), (1, 3));
            assert_eq!(chunk[(0, 0)], i as f64);
            assert_eq!(ch.pending(), 0);
        }
        assert!(ch.take_partial().is_none(), "size-1 chunker never buffers");
        // And the block path emits one chunk per row.
        let block = Mat64::from_fn(4, 3, |i, _| i as f64);
        let mut emitted = 0;
        ch.push_block(&block, |_| -> Result<(), ()> {
            emitted += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(emitted, 4);
        assert_eq!(ch.total_pushed(), 9);
    }

    #[test]
    fn blocks_straddling_chunk_boundaries_preserve_order() {
        // Block size 3 against chunk size 5: every chunk boundary lands
        // mid-block; the emitted stream must still be the identity
        // sequence with correct chunk shapes.
        let mut ch = Chunker::new(1, 5);
        let mut seen = Vec::new();
        let mut next = 0.0;
        for _ in 0..4 {
            let block = Mat64::from_fn(3, 1, |_, _| {
                let v = next;
                next += 1.0;
                v
            });
            ch.push_block(&block, |chunk| -> Result<(), ()> {
                assert_eq!(chunk.shape(), (5, 1));
                seen.extend_from_slice(chunk.as_slice());
                Ok(())
            })
            .unwrap();
        }
        assert_eq!(seen, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0]);
        assert_eq!(ch.pending(), 2, "12 pushed, 10 emitted");
        assert_eq!(ch.take_partial().unwrap().as_slice(), &[10.0, 11.0]);
    }
}
