//! Chunker: groups per-sample events into fixed-size row-major chunks for
//! the engines — the software analogue of the paper's "one sample per
//! clock into the pipeline" ingestion, with the chunk boundary playing the
//! role of the mini-batch boundary.

use crate::linalg::Mat64;

/// Accumulates samples (rows) until a full `chunk × m` matrix is ready.
pub struct Chunker {
    m: usize,
    chunk: usize,
    buf: Vec<f64>,
    rows: usize,
    total: u64,
}

impl Chunker {
    pub fn new(m: usize, chunk: usize) -> Self {
        assert!(m >= 1 && chunk >= 1);
        Self { m, chunk, buf: Vec::with_capacity(m * chunk), rows: 0, total: 0 }
    }

    /// Push one sample; returns a full chunk when ready.
    pub fn push(&mut self, x: &[f64]) -> Option<Mat64> {
        assert_eq!(x.len(), self.m, "sample dimensionality mismatch");
        self.buf.extend_from_slice(x);
        self.rows += 1;
        self.total += 1;
        if self.rows == self.chunk {
            let mat = Mat64::from_slice(self.chunk, self.m, &self.buf);
            self.buf.clear();
            self.rows = 0;
            Some(mat)
        } else {
            None
        }
    }

    /// Push every row of a block, invoking `on_chunk` for each completed
    /// chunk. This is the hub/server ingest path: one call per producer
    /// block instead of one `Option` check per sample at the call site.
    /// Stops at the first error.
    pub fn push_block<E>(
        &mut self,
        block: &Mat64,
        mut on_chunk: impl FnMut(&Mat64) -> Result<(), E>,
    ) -> Result<(), E> {
        for r in 0..block.rows() {
            if let Some(chunk) = self.push(block.row(r)) {
                on_chunk(&chunk)?;
            }
        }
        Ok(())
    }

    /// Samples currently buffered (not yet emitted).
    pub fn pending(&self) -> usize {
        self.rows
    }

    /// Total samples pushed over the lifetime.
    pub fn total_pushed(&self) -> u64 {
        self.total
    }

    /// Drain the partial tail (fewer than `chunk` rows), if any.
    ///
    /// The PJRT engine cannot run partial chunks (fixed-shape programs);
    /// the server either drops the tail (recording it in the summary) or
    /// routes it to a native fallback.
    pub fn take_partial(&mut self) -> Option<Mat64> {
        if self.rows == 0 {
            return None;
        }
        let mat = Mat64::from_slice(self.rows, self.m, &self.buf);
        self.buf.clear();
        self.rows = 0;
        Some(mat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_every_chunk() {
        let mut ch = Chunker::new(2, 3);
        assert!(ch.push(&[1.0, 2.0]).is_none());
        assert!(ch.push(&[3.0, 4.0]).is_none());
        let full = ch.push(&[5.0, 6.0]).expect("full chunk");
        assert_eq!(full.shape(), (3, 2));
        assert_eq!(full[(2, 1)], 6.0);
        assert_eq!(ch.pending(), 0);
    }

    #[test]
    fn preserves_order() {
        let mut ch = Chunker::new(1, 4);
        for i in 0..3 {
            assert!(ch.push(&[i as f64]).is_none());
        }
        let full = ch.push(&[3.0]).unwrap();
        assert_eq!(full.as_slice(), &[0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn partial_tail() {
        let mut ch = Chunker::new(2, 4);
        ch.push(&[1.0, 2.0]);
        ch.push(&[3.0, 4.0]);
        let tail = ch.take_partial().unwrap();
        assert_eq!(tail.shape(), (2, 2));
        assert!(ch.take_partial().is_none());
        assert_eq!(ch.total_pushed(), 2);
    }

    #[test]
    fn counts_across_chunks() {
        let mut ch = Chunker::new(1, 2);
        let mut chunks = 0;
        for i in 0..10 {
            if ch.push(&[i as f64]).is_some() {
                chunks += 1;
            }
        }
        assert_eq!(chunks, 5);
        assert_eq!(ch.total_pushed(), 10);
    }

    #[test]
    fn push_block_emits_chunks_in_order() {
        let mut ch = Chunker::new(1, 2);
        let block = Mat64::from_fn(5, 1, |i, _| i as f64);
        let mut seen = Vec::new();
        ch.push_block(&block, |chunk| -> Result<(), ()> {
            seen.extend_from_slice(chunk.as_slice());
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(ch.pending(), 1, "5th row stays buffered");
    }

    #[test]
    fn push_block_stops_on_error() {
        let mut ch = Chunker::new(1, 1);
        let block = Mat64::from_fn(4, 1, |i, _| i as f64);
        let mut calls = 0;
        let res = ch.push_block(&block, |_| {
            calls += 1;
            if calls == 2 {
                Err("boom")
            } else {
                Ok(())
            }
        });
        assert_eq!(res, Err("boom"));
        assert_eq!(calls, 2, "chunks after the error must not be emitted");
    }

    #[test]
    #[should_panic(expected = "dimensionality")]
    fn wrong_dim_panics() {
        let mut ch = Chunker::new(3, 2);
        ch.push(&[1.0]);
    }

    #[test]
    fn partial_flush_at_stream_end_via_push_block() {
        // The server's end-of-stream path: a block leaves a partial chunk
        // buffered; take_partial drains exactly those rows, in order, and
        // the chunker is reusable afterwards.
        let mut ch = Chunker::new(2, 4);
        let block = Mat64::from_fn(6, 2, |i, j| (2 * i + j) as f64);
        let mut chunks = 0;
        ch.push_block(&block, |c| -> Result<(), ()> {
            assert_eq!(c.shape(), (4, 2));
            chunks += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(chunks, 1);
        assert_eq!(ch.pending(), 2);
        let tail = ch.take_partial().expect("partial tail");
        assert_eq!(tail.shape(), (2, 2));
        assert_eq!(tail.as_slice(), &[8.0, 9.0, 10.0, 11.0]);
        assert_eq!(ch.pending(), 0);
        assert!(ch.take_partial().is_none(), "double drain must be empty");
        // Reusable: the next pushes start a fresh chunk.
        assert!(ch.push(&[0.0, 0.0]).is_none());
        assert_eq!(ch.pending(), 1);
        assert_eq!(ch.total_pushed(), 7);
    }

    #[test]
    fn chunk_size_one_emits_every_sample() {
        let mut ch = Chunker::new(3, 1);
        for i in 0..5 {
            let x = [i as f64, 0.0, 0.0];
            let chunk = ch.push(&x).expect("chunk size 1 emits per push");
            assert_eq!(chunk.shape(), (1, 3));
            assert_eq!(chunk[(0, 0)], i as f64);
            assert_eq!(ch.pending(), 0);
        }
        assert!(ch.take_partial().is_none(), "size-1 chunker never buffers");
        // And the block path emits one chunk per row.
        let block = Mat64::from_fn(4, 3, |i, _| i as f64);
        let mut emitted = 0;
        ch.push_block(&block, |_| -> Result<(), ()> {
            emitted += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(emitted, 4);
        assert_eq!(ch.total_pushed(), 9);
    }

    #[test]
    fn blocks_straddling_chunk_boundaries_preserve_order() {
        // Block size 3 against chunk size 5: every chunk boundary lands
        // mid-block; the emitted stream must still be the identity
        // sequence with correct chunk shapes.
        let mut ch = Chunker::new(1, 5);
        let mut seen = Vec::new();
        let mut next = 0.0;
        for _ in 0..4 {
            let block = Mat64::from_fn(3, 1, |_, _| {
                let v = next;
                next += 1.0;
                v
            });
            ch.push_block(&block, |chunk| -> Result<(), ()> {
                assert_eq!(chunk.shape(), (5, 1));
                seen.extend_from_slice(chunk.as_slice());
                Ok(())
            })
            .unwrap();
        }
        assert_eq!(seen, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0]);
        assert_eq!(ch.pending(), 2, "12 pushed, 10 emitted");
        assert_eq!(ch.take_partial().unwrap().as_slice(), &[10.0, 11.0]);
    }
}
