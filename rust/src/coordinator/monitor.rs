//! Online convergence monitor: tracks the Amari index of `C = B·A(t)`
//! against the (simulation-provided) ground-truth mixing matrix, keeps the
//! trajectory for reports, and detects convergence with the same criterion
//! as the offline experiment driver (`ica::convergence`).

use crate::ica::metrics::amari_index;
use crate::ica::ConvergenceCriterion;
use crate::linalg::Mat64;

/// One monitor observation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MonitorPoint {
    pub samples: u64,
    pub amari: f64,
}

/// Online Amari-index tracker with convergence detection.
pub struct Monitor {
    criterion: ConvergenceCriterion,
    history: Vec<MonitorPoint>,
    streak: usize,
    converged_at: Option<u64>,
    streak_start: u64,
}

impl Monitor {
    pub fn new(criterion: ConvergenceCriterion) -> Self {
        Self {
            criterion,
            history: Vec::new(),
            streak: 0,
            converged_at: None,
            streak_start: 0,
        }
    }

    /// Record an observation of B against the current true mixing `a`.
    /// Returns the Amari index.
    pub fn record(&mut self, b: &Mat64, a: &Mat64, samples: u64) -> f64 {
        let c = b.matmul(a);
        let amari = amari_index(&c);
        self.history.push(MonitorPoint { samples, amari });
        if self.converged_at.is_none() {
            if amari < self.criterion.threshold {
                if self.streak == 0 {
                    self.streak_start = samples;
                }
                self.streak += 1;
                if self.streak >= self.criterion.patience {
                    self.converged_at = Some(self.streak_start);
                }
            } else {
                self.streak = 0;
            }
        }
        amari
    }

    /// Reset convergence detection (e.g. after a known mixing switch) but
    /// keep the history.
    pub fn rearm(&mut self) {
        self.streak = 0;
        self.converged_at = None;
    }

    /// Sample count at which convergence was first declared.
    pub fn converged_at(&self) -> Option<u64> {
        self.converged_at
    }

    pub fn history(&self) -> &[MonitorPoint] {
        &self.history
    }

    /// Latest Amari value, if any observation was recorded.
    pub fn latest(&self) -> Option<MonitorPoint> {
        self.history.last().copied()
    }

    /// Serialize the trajectory and convergence-detection state
    /// (detach-to-disk; the criterion is config-derived at rebuild time).
    pub fn save_state(&self, w: &mut crate::snapshot::SnapWriter) {
        w.put_u64(self.history.len() as u64);
        for p in &self.history {
            w.put_u64(p.samples);
            w.put_f64(p.amari);
        }
        w.put_usize(self.streak);
        w.put_opt_u64(self.converged_at);
        w.put_u64(self.streak_start);
    }

    /// Rehydrate the state written by [`save_state`](Self::save_state).
    pub fn load_state(&mut self, r: &mut crate::snapshot::SnapReader<'_>) -> anyhow::Result<()> {
        let len = r.get_u64()? as usize;
        let mut history = Vec::with_capacity(len.min(1 << 20));
        for _ in 0..len {
            let samples = r.get_u64()?;
            let amari = r.get_f64()?;
            history.push(MonitorPoint { samples, amari });
        }
        self.history = history;
        self.streak = r.get_usize()?;
        self.converged_at = r.get_opt_u64()?;
        self.streak_start = r.get_u64()?;
        Ok(())
    }

    /// Worst (max) Amari over the last `k` observations — used by the
    /// adaptive-tracking experiment to quantify re-convergence dips.
    pub fn recent_max(&self, k: usize) -> Option<f64> {
        if self.history.is_empty() {
            return None;
        }
        let start = self.history.len().saturating_sub(k);
        self.history[start..]
            .iter()
            .map(|p| p.amari)
            .fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.max(v))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn crit() -> ConvergenceCriterion {
        ConvergenceCriterion { threshold: 0.1, check_every: 1, patience: 2 }
    }

    #[test]
    fn detects_convergence_streak() {
        let mut mon = Monitor::new(crit());
        let a = Mat64::eye(2, 2);
        // Identity C: amari 0 < 0.1.
        let b_good = Mat64::eye(2, 2);
        let b_bad = Mat64::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]);
        mon.record(&b_good, &a, 100);
        assert!(mon.converged_at().is_none(), "patience 2 needs 2 hits");
        mon.record(&b_bad, &a, 200); // breaks the streak
        mon.record(&b_good, &a, 300);
        mon.record(&b_good, &a, 400);
        assert_eq!(mon.converged_at(), Some(300));
    }

    #[test]
    fn rearm_clears_convergence() {
        let mut mon = Monitor::new(crit());
        let a = Mat64::eye(2, 2);
        let b = Mat64::eye(2, 2);
        mon.record(&b, &a, 1);
        mon.record(&b, &a, 2);
        assert!(mon.converged_at().is_some());
        mon.rearm();
        assert!(mon.converged_at().is_none());
        assert_eq!(mon.history().len(), 2, "history preserved");
    }

    #[test]
    fn rearm_reports_second_convergence_after_switch() {
        // The re-convergence contract the adaptive control plane relies
        // on: after an abrupt mixing switch (simulated by bad records) and
        // a rearm, the monitor must latch a *second* converged_at rather
        // than staying on the pre-switch one.
        let mut mon = Monitor::new(crit());
        let a = Mat64::eye(2, 2);
        let b_good = Mat64::eye(2, 2);
        let b_bad = Mat64::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]);
        mon.record(&b_good, &a, 100);
        mon.record(&b_good, &a, 200);
        assert_eq!(mon.converged_at(), Some(100), "first convergence");
        // Mixing switch: the control plane rearms; the separator is bad
        // for a while, then re-converges.
        mon.rearm();
        mon.record(&b_bad, &a, 300);
        mon.record(&b_bad, &a, 400);
        assert_eq!(mon.converged_at(), None, "must not stay latched");
        mon.record(&b_good, &a, 500);
        mon.record(&b_good, &a, 600);
        assert_eq!(mon.converged_at(), Some(500), "second convergence reported");
        assert_eq!(mon.history().len(), 6, "history spans both regimes");
    }

    #[test]
    fn recent_max_window() {
        let mut mon = Monitor::new(crit());
        let a = Mat64::eye(2, 2);
        let mk = |v: f64| {
            Mat64::from_rows(&[&[1.0, v], &[v, 1.0]])
        };
        for (i, v) in [0.0, 0.9, 0.1, 0.05].iter().enumerate() {
            mon.record(&mk(*v), &a, i as u64);
        }
        let recent = mon.recent_max(2).unwrap();
        let all = mon.recent_max(100).unwrap();
        assert!(recent < all, "recent window should exclude the 0.9 spike");
    }
}
