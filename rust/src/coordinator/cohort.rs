//! Cohort executor: tenant-major batching for the worker hot loop.
//!
//! Both hub flavours used to step one session's chunk at a time — for the
//! millions-of-small-tenants regime that wastes the worker on per-session
//! loop setup and dispatch instead of flops. The [`CohortExecutor`] sits
//! between a shard's event loop and its [`SessionRunner`]s and regroups
//! the work *tenant-major*: sessions with the same shape key
//! (`n`, `m`, chunk size, nonlinearity, precision, optimizer form) form a
//! *pool*, and one pool step advances every ready member through a single
//! tenant-major kernel whose inner loops run across the tenants —
//! [`CohortState`] for plain fused EASI-SGD lanes, [`CohortSmbgdState`]
//! for plain SMBGD lanes (the mini-batch accumulator `Ĥ_prev` rides the
//! same load/store wire as `B`). The optimizer form is a key dimension:
//! SGD and SMBGD tenants never share a pool, and SMBGD pools additionally
//! key on the mini-batch size `P` (the kernel steps whole mini-batches in
//! lockstep; `μ`, `γ`, `β` stay per-lane data and may differ freely).
//!
//! ## Ordering and bit-identity
//!
//! Cohort execution is a pure re-scheduling: each session's event
//! sequence (chunk applied → bookkeeping → mixing snapshot → …) is
//! exactly the per-session order — only *when* a chunk runs relative to
//! other sessions' chunks changes, and sessions are independent. Combined
//! with the per-lane bit-identity of the [`CohortState`] kernels, a
//! session's trajectory (B bits, Amari history, reset/drift counters) is
//! identical with the executor on or off, under every build. Pinned by
//! `tests/integration_cohort.rs`.
//!
//! Each pool step reloads every lane's state from its engine — `(B, μ)`
//! for SGD lanes, `(B, Ĥ_prev, μ, γ, β)` for SMBGD lanes — so
//! divergence-guard resets and the adaptive governor's μ retunes feed
//! back into the very next step, exactly as on the per-session path.
//! SMBGD chunks hold whole mini-batches by construction (the native
//! chunk size is `8·P`), so every pool step runs boundary-to-boundary
//! and the engine's latched mini-batch counter advances exactly as solo.
//!
//! ## Membership lifecycle
//!
//! - `register` at admission: eligible sessions (plain fused EASI-SGD or
//!   plain SMBGD native engines — [`SessionRunner::cohort_lane`]) join
//!   the pool for their shape key; everything else stays on the
//!   per-session path.
//! - A member without peers (pool of one) is routed straight through
//!   `SessionRunner::on_block` — the fall-back the issue requires — and
//!   its queue is kept empty so there is nothing to extract.
//! - `finish_session` (End, park, detach) drains the member's queued
//!   items in order through the ordinary per-session path and removes it:
//!   the runner is self-contained again, so the PR-5 park/reattach
//!   bit-identity pins hold unchanged. If the pool drops to one member,
//!   the survivor's queue is drained too (it reverts to the direct path).
//!   When the *last* member departs the pool itself is dropped — a
//!   zero-lane pool would otherwise park its grown kernel state and
//!   scratch forever (the shape key readmits with fresh, right-sized
//!   buffers if tenants of that shape ever return).
//! - `flush_session` (checkpoint/restore) drains without removing, so a
//!   `Restore`'s `install_b` lands on a fully caught-up runner.
//! - A lane whose divergence guard **latches a fault** mid-pump (its
//!   separator stayed non-finite through the rollback/reset retry
//!   budget) is extracted from its pool without perturbing sibling
//!   lanes: its queued items are dropped (the runner is quarantined by
//!   the shard, not caught up) and the id is reported through
//!   [`CohortExecutor::take_faulted`]. Lanes are mathematically
//!   independent, so extraction cannot change a sibling's bitwise
//!   trajectory — pinned by `surviving_lanes_are_bitwise_unperturbed_*`.
//!
//! ## Batching policy
//!
//! Chunks queue per lane; a pool steps when every member has a chunk
//! ready (full-width step) or when any member's backlog reaches
//! [`MAX_LAG`] items (then the ready subset steps, bounding latency and
//! memory when producers run at different speeds or a member idles).

use super::engine::{native_chunk_size, CohortLaneForm};
use super::server::SessionRunner;
use super::state::StatusCell;
use crate::config::{EngineKind, ExperimentConfig, OptimizerKind, Precision};
use crate::ica::nonlinearity::{with_g, Nonlinearity};
use crate::linalg::{CohortSmbgdState, CohortState, Mat64, Scalar};
use anyhow::Result;
use std::collections::{BTreeMap, VecDeque};

/// Backlog bound (queued items per lane) that forces a partial-width pool
/// step. One producer block is four chunks at the default chunk size, so
/// 8 keeps at most two blocks buffered per lane.
const MAX_LAG: usize = 8;

/// The optimizer-form dimension of the pool key. SGD and SMBGD lanes run
/// different kernels, so they never pool together; SMBGD pools further
/// key on the mini-batch size `P` because the kernel steps whole
/// mini-batches in lockstep. Per-lane hyperparameters (`μ`, `γ`, `β`)
/// deliberately stay out of the key — they are lane data, reloaded fresh
/// every step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum OptimizerForm {
    Sgd,
    Smbgd { p: usize },
}

/// Shape key pooling compatible tenants: lanes must agree on the matrix
/// shape (one SoA block), the chunk size (lockstep rows), the
/// nonlinearity (one monomorphized kernel), the precision (one scalar
/// type) and the optimizer form (one kernel family).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct CohortKey {
    n: usize,
    m: usize,
    chunk: usize,
    g: Nonlinearity,
    precision: Precision,
    form: OptimizerForm,
}

/// Hub-side mirror of the admission rule: the pool key a session built
/// from `cfg` *would* join, or `None` when it will stay per-session.
/// This feeds shape-aware placement before a runner exists, so it
/// re-derives eligibility from the config alone: native engine family,
/// f64/f32 precision, and a pooled optimizer form (plain SGD or SMBGD).
/// It is a placement *hint* only — [`CohortExecutor::register`], driven
/// by the live engine's [`SessionRunner::cohort_lane`] probe, remains
/// the source of truth; a mismatch costs locality, never correctness.
pub(crate) fn affinity_key(cfg: &ExperimentConfig, g: Nonlinearity) -> Option<CohortKey> {
    if cfg.engine != EngineKind::Native {
        return None;
    }
    if !matches!(cfg.precision, Precision::F64 | Precision::F32) {
        return None;
    }
    let form = match cfg.optimizer.kind {
        OptimizerKind::Sgd => OptimizerForm::Sgd,
        OptimizerKind::Smbgd => OptimizerForm::Smbgd { p: cfg.optimizer.p },
        OptimizerKind::Mbgd => return None,
    };
    Some(CohortKey {
        n: cfg.n,
        m: cfg.m,
        chunk: native_chunk_size(cfg),
        g,
        precision: cfg.precision,
        form,
    })
}

/// One queued per-lane event, preserving the session's event order: a
/// mixing snapshot queued behind a chunk is applied only after that
/// chunk's bookkeeping, exactly as on the per-session path.
enum LaneItem {
    Chunk(Mat64),
    Mixing(Mat64),
}

/// The pool's kernel state, monomorphized per precision and optimizer
/// form.
enum PoolState {
    F64(CohortState<f64>),
    F32(CohortState<f32>),
    F64Smbgd(CohortSmbgdState<f64>),
    F32Smbgd(CohortSmbgdState<f32>),
}

/// One shape-key pool: member queues plus reusable step scratch.
struct Pool<K: Ord + Copy> {
    key: CohortKey,
    state: PoolState,
    /// Per-member FIFO, keyed by session id — `BTreeMap` so lane order
    /// within a step is deterministic (ascending id).
    pending: BTreeMap<K, VecDeque<LaneItem>>,
    /// Scratch: ids stepping this round (reused across steps).
    ready: Vec<K>,
    /// Scratch: the chunks popped for this step, lane-ordered.
    chunks: Vec<Mat64>,
    /// Scratch: completed chunks from one block ingest.
    ingested: Vec<Mat64>,
    /// Scratch: per-lane B staging for store/sync (grown once).
    bs: Vec<Mat64>,
    /// Scratch: per-lane `Ĥ_prev` staging (SMBGD pools only; grown once).
    hs: Vec<Mat64>,
}

impl<K: Ord + Copy> Pool<K> {
    fn new(key: CohortKey) -> Self {
        let state = match (key.precision, key.form) {
            (Precision::F64, OptimizerForm::Sgd) => {
                PoolState::F64(CohortState::new(key.n, key.m))
            }
            (Precision::F32, OptimizerForm::Sgd) => {
                PoolState::F32(CohortState::new(key.n, key.m))
            }
            (Precision::F64, OptimizerForm::Smbgd { p }) => {
                PoolState::F64Smbgd(CohortSmbgdState::new(key.n, key.m, p))
            }
            (Precision::F32, OptimizerForm::Smbgd { p }) => {
                PoolState::F32Smbgd(CohortSmbgdState::new(key.n, key.m, p))
            }
            // Engines never offer a fixed-point cohort lane
            // (`CastNativeEngine::cohort_lane` returns `None` for q16/q32
            // so the saturation latch stays attributed per session).
            (Precision::Q16 | Precision::Q32, _) => {
                unreachable!("fixed-point precisions do not offer cohort lanes")
            }
        };
        Self {
            key,
            state,
            pending: BTreeMap::new(),
            ready: Vec::new(),
            chunks: Vec::new(),
            ingested: Vec::new(),
            bs: Vec::new(),
            hs: Vec::new(),
        }
    }
}

/// Dispatch the nonlinearity once per pool step (the same `with_g!` seam
/// the per-session optimizer uses, so the monomorphized closures match).
fn step_pool_state<T: Scalar>(st: &mut CohortState<T>, g: Nonlinearity, chunks: &[Mat64]) {
    with_g!(T, g, gf => st.step_chunks(gf, chunks));
}

/// Drain one lane's queue in order through the per-session path.
fn drain_lane(q: &mut VecDeque<LaneItem>, runner: &mut SessionRunner) -> Result<()> {
    while let Some(item) = q.pop_front() {
        match item {
            LaneItem::Chunk(c) => runner.apply_chunk(&c)?,
            LaneItem::Mixing(a) => runner.on_mixing(a),
        }
    }
    Ok(())
}

/// Run pool steps until the batching policy says wait: apply front-of-
/// queue mixing snapshots, then step every ready lane through the fused
/// cohort kernel and feed the results back into the runners.
fn pump<K: Ord + Copy>(
    pool: &mut Pool<K>,
    runners: &mut BTreeMap<K, SessionRunner>,
    faulted: &mut Vec<K>,
) -> Result<()> {
    loop {
        // Front-of-queue mixing snapshots are ready to apply: everything
        // ordered before them has been stepped and noted.
        for (id, q) in pool.pending.iter_mut() {
            while matches!(q.front(), Some(LaneItem::Mixing(_))) {
                if let Some(LaneItem::Mixing(a)) = q.pop_front() {
                    if let Some(r) = runners.get_mut(id) {
                        r.on_mixing(a);
                    }
                }
            }
        }
        pool.ready.clear();
        let mut max_backlog = 0;
        for (id, q) in pool.pending.iter() {
            if matches!(q.front(), Some(LaneItem::Chunk(_))) {
                pool.ready.push(*id);
            }
            max_backlog = max_backlog.max(q.len());
        }
        if pool.ready.is_empty() {
            return Ok(());
        }
        // Prefer full-width steps; break lockstep only when a lane's
        // backlog says waiting costs latency/memory.
        if pool.ready.len() < pool.pending.len() && max_backlog < MAX_LAG {
            return Ok(());
        }

        let lanes = pool.ready.len();
        pool.chunks.clear();
        for id in pool.ready.iter() {
            match pool.pending.get_mut(id).and_then(VecDeque::pop_front) {
                Some(LaneItem::Chunk(c)) => pool.chunks.push(c),
                _ => unreachable!("ready lane must front a chunk"),
            }
        }
        while pool.bs.len() < lanes {
            pool.bs.push(Mat64::zeros(pool.key.n, pool.key.m));
        }
        if matches!(pool.state, PoolState::F64Smbgd(_) | PoolState::F32Smbgd(_)) {
            while pool.hs.len() < lanes {
                pool.hs.push(Mat64::zeros(pool.key.n, pool.key.n));
            }
        }
        let before = faulted.len();
        match &mut pool.state {
            PoolState::F64(st) => {
                step_loaded(
                    st, pool.key.g, &pool.ready, &pool.chunks, &mut pool.bs, runners, faulted,
                )?;
            }
            PoolState::F32(st) => {
                step_loaded(
                    st, pool.key.g, &pool.ready, &pool.chunks, &mut pool.bs, runners, faulted,
                )?;
            }
            PoolState::F64Smbgd(st) => {
                step_loaded_smbgd(
                    st,
                    pool.key.g,
                    &pool.ready,
                    &pool.chunks,
                    &mut pool.bs,
                    &mut pool.hs,
                    runners,
                    faulted,
                )?;
            }
            PoolState::F32Smbgd(st) => {
                step_loaded_smbgd(
                    st,
                    pool.key.g,
                    &pool.ready,
                    &pool.chunks,
                    &mut pool.bs,
                    &mut pool.hs,
                    runners,
                    faulted,
                )?;
            }
        }
        // Lanes whose divergence guard latched a fault this step leave
        // the pool now: drop their poisoned queues (the shard quarantines
        // the runner; catching it up would only repeat the rollback) and
        // keep pumping the survivors. Lane independence means removal
        // cannot perturb a sibling's bits.
        if faulted.len() > before {
            for id in faulted[before..].iter() {
                pool.pending.remove(id);
            }
            if pool.pending.len() == 1 {
                // Pool of one reverts to the per-session path: catch the
                // survivor up. If *its* drain latches a fault too, report
                // it the same way instead of leaving it latent.
                let (&sid, q) = pool.pending.iter_mut().next().expect("len checked");
                if let Some(r) = runners.get_mut(&sid) {
                    drain_lane(q, r)?;
                    if r.fault().is_some() {
                        pool.pending.remove(&sid);
                        faulted.push(sid);
                    }
                }
            }
        }
    }
}

/// One pool step at a fixed precision: load every ready lane's `(B, μ)`
/// fresh from its engine, run the fused cohort kernel, then store each
/// lane back and run its per-chunk bookkeeping — the exact
/// `submit_chunk` → bookkeeping sequence of the per-session path, per
/// lane, in ascending session-id order.
fn step_loaded<T: Scalar, K: Ord + Copy>(
    st: &mut CohortState<T>,
    g: Nonlinearity,
    ready: &[K],
    chunks: &[Mat64],
    bs: &mut [Mat64],
    runners: &mut BTreeMap<K, SessionRunner>,
    faulted: &mut Vec<K>,
) -> Result<()> {
    st.begin(ready.len());
    for (l, id) in ready.iter().enumerate() {
        let r = runners.get(id).expect("cohort member has a runner");
        let lane = r.cohort_lane().expect("cohort member kept its lane");
        st.load_lane(l, &r.cohort_b(), lane.mu);
    }
    step_pool_state(st, g, chunks);
    for (l, id) in ready.iter().enumerate() {
        st.store_lane(l, &mut bs[l]);
        let r = runners.get_mut(id).expect("cohort member has a runner");
        r.cohort_sync(&bs[l], chunks[l].rows() as u64);
        r.note_cohort_chunk(&chunks[l]);
        if r.fault().is_some() {
            faulted.push(*id);
        }
    }
    Ok(())
}

/// One SMBGD pool step: like [`step_loaded`], but each lane's load/store
/// wire additionally carries the cross-batch accumulator `Ĥ_prev` and
/// the `(γ, β)` hyperparameters from the lane's freshly probed form.
/// Eligibility (`cohort_smbgd`) holds exactly at batch boundaries, and
/// cohort chunks are whole mini-batches, so the probe stays `Some` for
/// the life of the membership.
fn step_loaded_smbgd<T: Scalar, K: Ord + Copy>(
    st: &mut CohortSmbgdState<T>,
    g: Nonlinearity,
    ready: &[K],
    chunks: &[Mat64],
    bs: &mut [Mat64],
    hs: &mut [Mat64],
    runners: &mut BTreeMap<K, SessionRunner>,
    faulted: &mut Vec<K>,
) -> Result<()> {
    st.begin(ready.len());
    for (l, id) in ready.iter().enumerate() {
        let r = runners.get(id).expect("cohort member has a runner");
        let lane = r.cohort_lane().expect("cohort member kept its lane");
        let CohortLaneForm::Smbgd { gamma, beta, .. } = lane.form else {
            unreachable!("SMBGD pool admitted a non-SMBGD lane")
        };
        st.load_lane(l, &r.cohort_b(), &r.cohort_hhat_prev(), lane.mu, gamma, beta);
    }
    with_g!(T, g, gf => st.step_chunks(gf, chunks));
    for (l, id) in ready.iter().enumerate() {
        st.store_lane(l, &mut bs[l], &mut hs[l]);
        let r = runners.get_mut(id).expect("cohort member has a runner");
        r.cohort_sync_smbgd(&bs[l], &hs[l], chunks[l].rows() as u64);
        r.note_cohort_chunk(&chunks[l]);
        if r.fault().is_some() {
            faulted.push(*id);
        }
    }
    Ok(())
}

/// Per-shard cohort scheduler: owns the pools and routes each session
/// event either through a cohort pool or straight to the session's
/// runner. Generic over the shard's session-id key (`usize` in the batch
/// hub, `u64` in the elastic hub).
pub(crate) struct CohortExecutor<K: Ord + Copy = u64> {
    enabled: bool,
    pools: Vec<Pool<K>>,
    /// Members only: session id → pool index.
    index: BTreeMap<K, usize>,
    /// Lanes extracted mid-pump because their divergence guard latched a
    /// fault, awaiting pickup via [`Self::take_faulted`].
    faulted: Vec<K>,
    /// Members' health records, for publishing pool widths to the status
    /// plane (the `pool` column and the hub's `pool_occupancy`).
    cells: BTreeMap<K, StatusCell>,
}

impl<K: Ord + Copy> CohortExecutor<K> {
    pub(crate) fn new(enabled: bool) -> Self {
        Self {
            enabled,
            pools: Vec::new(),
            index: BTreeMap::new(),
            faulted: Vec::new(),
            cells: BTreeMap::new(),
        }
    }

    /// Admit a session: eligible runners (cohort-capable engines) join
    /// the pool for their shape key; the rest stay on the per-session
    /// path. Idempotent per id.
    pub(crate) fn register(&mut self, id: K, runner: &SessionRunner) {
        if !self.enabled || self.index.contains_key(&id) {
            return;
        }
        let Some(lane) = runner.cohort_lane() else { return };
        let (n, m) = runner.shape();
        let form = match lane.form {
            CohortLaneForm::Sgd => OptimizerForm::Sgd,
            // γ and β are per-lane data (reloaded every step); only the
            // lockstep mini-batch size P shapes the pool.
            CohortLaneForm::Smbgd { p, .. } => OptimizerForm::Smbgd { p },
        };
        let key = CohortKey {
            n,
            m,
            chunk: runner.chunk_size(),
            g: lane.g,
            precision: lane.precision,
            form,
        };
        let pi = match self.pools.iter().position(|p| p.key == key) {
            Some(pi) => pi,
            None => {
                self.pools.push(Pool::new(key));
                self.pools.len() - 1
            }
        };
        self.pools[pi].pending.insert(id, VecDeque::new());
        self.index.insert(id, pi);
        self.cells.insert(id, runner.status_cell());
        // Publish the new width to every member of the affected pool —
        // the cells record the *peak* width, so no publish on shrink.
        let width = self.pools[pi].pending.len();
        for mid in self.pools[pi].pending.keys() {
            if let Some(cell) = self.cells.get(mid) {
                cell.set_pool_width(width);
            }
        }
    }

    /// Whether a session currently runs as a cohort lane (tests).
    #[cfg(test)]
    pub(crate) fn is_member(&self, id: K) -> bool {
        self.index.contains_key(&id)
    }

    /// Route one producer block: members with peers ingest (AGC + chunk)
    /// into their lane queue and the pool pumps; everyone else takes the
    /// unchanged per-session path.
    pub(crate) fn on_block(
        &mut self,
        id: K,
        block: Mat64,
        runners: &mut BTreeMap<K, SessionRunner>,
    ) -> Result<()> {
        if let Some(&pi) = self.index.get(&id) {
            let pool = &mut self.pools[pi];
            if pool.pending.len() >= 2 {
                let runner = runners.get_mut(&id).expect("cohort member has a runner");
                pool.ingested.clear();
                runner.ingest_block_into(block, &mut pool.ingested);
                let q = pool.pending.get_mut(&id).expect("member has a lane queue");
                for c in pool.ingested.drain(..) {
                    q.push_back(LaneItem::Chunk(c));
                }
                let before = self.faulted.len();
                pump(pool, runners, &mut self.faulted)?;
                // Extracted lanes lose membership immediately, so a late
                // block for one routes per-session (where the shard sees
                // the latched fault) instead of re-entering a pool.
                for fid in self.faulted[before..].to_vec() {
                    self.index.remove(&fid);
                    self.cells.remove(&fid);
                }
                self.drop_pool_if_empty(pi);
                return Ok(());
            }
            // Member without shape peers: per-session path, unchanged
            // (its queue is empty by the membership invariants).
        }
        runners.get_mut(&id).expect("session has a runner").on_block(block)
    }

    /// Session ids whose divergence guard latched a fault during cohort
    /// stepping since the last call (already removed from their pools and
    /// from membership). The shard worker quarantines these.
    pub(crate) fn take_faulted(&mut self) -> Vec<K> {
        std::mem::take(&mut self.faulted)
    }

    /// Route one mixing snapshot: queued behind any pending chunks so the
    /// lane's event order is preserved; applied directly when nothing is
    /// queued (which is exactly the per-session timing).
    pub(crate) fn on_mixing(
        &mut self,
        id: K,
        a: Mat64,
        runners: &mut BTreeMap<K, SessionRunner>,
    ) {
        if let Some(&pi) = self.index.get(&id) {
            let pool = &mut self.pools[pi];
            if pool.pending.len() >= 2 {
                let q = pool.pending.get_mut(&id).expect("member has a lane queue");
                if !q.is_empty() {
                    q.push_back(LaneItem::Mixing(a));
                    return;
                }
            }
        }
        if let Some(r) = runners.get_mut(&id) {
            r.on_mixing(a);
        }
    }

    /// Catch a member's runner up with everything queued for it (in
    /// order, through the per-session path) without changing membership —
    /// the checkpoint/restore hook: after this, the runner is exactly the
    /// session's per-session state.
    pub(crate) fn flush_session(
        &mut self,
        id: K,
        runners: &mut BTreeMap<K, SessionRunner>,
    ) -> Result<()> {
        if let Some(&pi) = self.index.get(&id) {
            if let Some(q) = self.pools[pi].pending.get_mut(&id) {
                if let Some(runner) = runners.get_mut(&id) {
                    drain_lane(q, runner)?;
                }
            }
        }
        Ok(())
    }

    /// Extract a session from its pool (End / park / detach): drain its
    /// queue so the runner is self-contained, then drop membership. A
    /// pool left with a single member has that survivor drained too — it
    /// reverts to the per-session path.
    pub(crate) fn finish_session(
        &mut self,
        id: K,
        runners: &mut BTreeMap<K, SessionRunner>,
    ) -> Result<()> {
        let Some(&pi) = self.index.get(&id) else { return Ok(()) };
        self.flush_session(id, runners)?;
        self.index.remove(&id);
        self.cells.remove(&id);
        let pool = &mut self.pools[pi];
        pool.pending.remove(&id);
        if pool.pending.len() == 1 {
            let (&sid, q) = pool.pending.iter_mut().next().expect("len checked");
            if let Some(r) = runners.get_mut(&sid) {
                drain_lane(q, r)?;
            }
        }
        self.drop_pool_if_empty(pi);
        Ok(())
    }

    /// Drop a pool whose last lane departed. A zero-lane pool would park
    /// its grown kernel state and step scratch indefinitely (nothing ever
    /// shrinks a live pool's buffers, by design), so the pool itself must
    /// go; readmission under the same key rebuilds one sized to the new
    /// tenants. `swap_remove` keeps this O(1); the pool that swapped into
    /// the hole gets its members' index entries remapped.
    fn drop_pool_if_empty(&mut self, pi: usize) {
        if !self.pools[pi].pending.is_empty() {
            return;
        }
        self.pools.swap_remove(pi);
        let moved = self.pools.len();
        if pi < moved {
            for v in self.index.values_mut() {
                if *v == moved {
                    *v = pi;
                }
            }
        }
    }

    /// Number of live pools. Pools exist only while they have members —
    /// pinned by the empty-pool regression tests.
    pub(crate) fn pool_count(&self) -> usize {
        self.pools.len()
    }

    /// Width (member count) of the pool `id` belongs to; `None` for
    /// non-members. Feeds the status table's `pool` column.
    pub(crate) fn pool_width(&self, id: K) -> Option<usize> {
        self.index.get(&id).map(|&pi| self.pools[pi].pending.len())
    }

    /// Cohort occupancy as `(sharing, members)`: how many members
    /// currently share a pool with at least one peer (and so actually
    /// step tenant-major), over all members. The hub turns this into the
    /// `pool_occupancy` fraction.
    pub(crate) fn occupancy(&self) -> (usize, usize) {
        let mut sharing = 0;
        let mut members = 0;
        for p in self.pools.iter() {
            members += p.pending.len();
            if p.pending.len() >= 2 {
                sharing += p.pending.len();
            }
        }
        (sharing, members)
    }

    /// Drain every queue (shutdown / producer-disconnect path) so the
    /// shard's leftover runners can be finished per-session.
    pub(crate) fn flush_all(&mut self, runners: &mut BTreeMap<K, SessionRunner>) -> Result<()> {
        for pool in self.pools.iter_mut() {
            for (id, q) in pool.pending.iter_mut() {
                if let Some(r) = runners.get_mut(id) {
                    drain_lane(q, r)?;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, OptimizerKind, Precision};
    use crate::coordinator::engine::make_engine;
    use crate::coordinator::server::{ServerOptions, SessionRunner};
    use crate::coordinator::state::StateStore;
    use crate::signal::Pcg32;

    fn sgd_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.optimizer.kind = OptimizerKind::Sgd;
        cfg.optimizer.mu = 0.004;
        cfg
    }

    fn smbgd_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.optimizer.kind = OptimizerKind::Smbgd;
        cfg.optimizer.mu = 0.004;
        cfg
    }

    fn runner_with_g(cfg: &ExperimentConfig, g: Nonlinearity) -> SessionRunner {
        let engine = make_engine(cfg, g).unwrap();
        let state = StateStore::new(crate::ica::init_b(cfg.n, cfg.m));
        SessionRunner::new(cfg, engine, &ServerOptions::default(), state)
    }

    fn runner(cfg: &ExperimentConfig) -> SessionRunner {
        runner_with_g(cfg, Nonlinearity::Cube)
    }

    fn blocks(seed: u64, count: usize, m: usize) -> Vec<Mat64> {
        let mut rng = Pcg32::seed(seed);
        (0..count).map(|_| Mat64::from_fn(256, m, |_, _| rng.normal())).collect()
    }

    /// Three same-shape sessions through the executor must finish with
    /// exactly the bits (and bookkeeping) of the same sessions run solo —
    /// the executor's core contract, checked for both kernel families.
    fn check_routing_matches_solo(cfg: &ExperimentConfig) {
        let cfg = cfg.clone();
        let a = Mat64::eye(cfg.m, cfg.n);
        // Three same-shape sessions through the executor…
        let mut runners: BTreeMap<u64, SessionRunner> = BTreeMap::new();
        let mut exec = CohortExecutor::<u64>::new(true);
        for id in 0..3u64 {
            let r = runner(&cfg);
            exec.register(id, &r);
            runners.insert(id, r);
        }
        assert!(exec.is_member(0) && exec.is_member(2));
        for id in 0..3u64 {
            exec.on_mixing(id, a.clone(), &mut runners);
        }
        for round in 0..4 {
            for id in 0..3u64 {
                let b = blocks(100 + id * 10 + round, 1, cfg.m).pop().unwrap();
                exec.on_block(id, b, &mut runners).unwrap();
                exec.on_mixing(id, a.clone(), &mut runners);
            }
        }
        let mut cohort_bs = Vec::new();
        for id in 0..3u64 {
            exec.finish_session(id, &mut runners).unwrap();
            cohort_bs.push(runners.remove(&id).unwrap().finish());
        }
        // …against the same sessions run solo.
        for (id, got) in cohort_bs.into_iter().enumerate() {
            let mut solo = runner(&cfg);
            solo.on_mixing(a.clone());
            for round in 0..4 {
                let b = blocks(100 + id as u64 * 10 + round, 1, cfg.m).pop().unwrap();
                solo.on_block(b).unwrap();
                solo.on_mixing(a.clone());
            }
            let want = solo.finish();
            assert_eq!(want.samples, got.samples, "session {id}");
            assert_eq!(want.tail_dropped, got.tail_dropped, "session {id}");
            assert!(
                want.b
                    .as_slice()
                    .iter()
                    .zip(got.b.as_slice())
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "session {id}: cohort B diverged from solo B"
            );
            assert_eq!(want.amari_history.len(), got.amari_history.len());
        }
    }

    #[test]
    fn cohort_routing_matches_solo_runners_bitwise() {
        check_routing_matches_solo(&sgd_cfg());
    }

    #[test]
    fn smbgd_cohort_routing_matches_solo_runners_bitwise() {
        check_routing_matches_solo(&smbgd_cfg());
    }

    #[test]
    fn lone_member_and_ineligible_sessions_take_the_solo_path() {
        let cfg = sgd_cfg();
        let smbgd = smbgd_cfg();
        let mut mbgd_cfg = cfg.clone();
        mbgd_cfg.optimizer.kind = OptimizerKind::Mbgd;

        let mut runners: BTreeMap<u64, SessionRunner> = BTreeMap::new();
        let mut exec = CohortExecutor::<u64>::new(true);
        let r0 = runner(&cfg);
        let r1 = runner(&mbgd_cfg);
        let r2 = runner(&smbgd);
        exec.register(0, &r0);
        exec.register(1, &r1);
        exec.register(2, &r2);
        runners.insert(0, r0);
        runners.insert(1, r1);
        runners.insert(2, r2);
        assert!(exec.is_member(0), "plain SGD is cohort-capable");
        assert!(!exec.is_member(1), "MBGD has no cohort kernel; it stays per-session");
        assert!(exec.is_member(2), "plain SMBGD is cohort-capable");
        assert_eq!(exec.pool_count(), 2, "SGD and SMBGD lanes must not share a pool");

        // Members without shape peers route straight through; their
        // samples land immediately (nothing queued).
        let b = blocks(7, 1, cfg.m).pop().unwrap();
        exec.on_block(0, b, &mut runners).unwrap();
        assert_eq!(runners.get(&0).unwrap().samples_done(), 256);
        let b = blocks(8, 1, cfg.m).pop().unwrap();
        exec.on_block(1, b, &mut runners).unwrap();
        assert!(runners.get(&1).unwrap().samples_done() > 0);
        let b = blocks(9, 1, cfg.m).pop().unwrap();
        exec.on_block(2, b, &mut runners).unwrap();
        assert_eq!(runners.get(&2).unwrap().samples_done(), 256);
    }

    /// μ/γ/β are lane data, not key dimensions: SMBGD tenants with
    /// different hyperparameters share one pool and still reproduce their
    /// solo trajectories bitwise.
    #[test]
    fn smbgd_pool_mixes_hyperparameters_bitwise() {
        let cfg_a = smbgd_cfg();
        let mut cfg_b = smbgd_cfg();
        cfg_b.optimizer.mu = 0.002;
        cfg_b.optimizer.gamma = 0.3;
        cfg_b.optimizer.beta = 0.95;
        let cfgs = [cfg_a, cfg_b];

        let mut runners: BTreeMap<u64, SessionRunner> = BTreeMap::new();
        let mut exec = CohortExecutor::<u64>::new(true);
        for (id, c) in cfgs.iter().enumerate() {
            let r = runner(c);
            exec.register(id as u64, &r);
            runners.insert(id as u64, r);
        }
        assert_eq!(exec.pool_count(), 1, "hyperparameters must not split the pool");
        assert_eq!(exec.pool_width(0), Some(2));
        for round in 0..4u64 {
            for id in 0..2u64 {
                let b = blocks(300 + id * 10 + round, 1, cfgs[0].m).pop().unwrap();
                exec.on_block(id, b, &mut runners).unwrap();
            }
        }
        for (id, c) in cfgs.iter().enumerate() {
            exec.finish_session(id as u64, &mut runners).unwrap();
            let got = runners.remove(&(id as u64)).unwrap().finish();
            let mut solo = runner(c);
            for round in 0..4u64 {
                let b = blocks(300 + id as u64 * 10 + round, 1, c.m).pop().unwrap();
                solo.on_block(b).unwrap();
            }
            let want = solo.finish();
            assert_eq!(want.samples, got.samples, "session {id}");
            assert!(
                want.b
                    .as_slice()
                    .iter()
                    .zip(got.b.as_slice())
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "session {id}: mixed-hyperparameter cohort diverged from solo"
            );
        }
    }

    #[test]
    fn disabled_executor_registers_nobody() {
        let cfg = sgd_cfg();
        let mut exec = CohortExecutor::<u64>::new(false);
        let r = runner(&cfg);
        exec.register(0, &r);
        assert!(!exec.is_member(0));
    }

    /// Property sweep over every key axis: no pool ever mixes shapes,
    /// precisions, nonlinearities or optimizer forms, and the hub-side
    /// placement hint ([`affinity_key`]) derives *exactly* the key the
    /// executor builds from the live engine probe — so shape-aware
    /// placement can never steer a session toward a pool it would then
    /// be refused from (or admitted to incorrectly).
    #[test]
    fn pools_never_mix_shape_precision_nonlinearity_or_form() {
        let shapes = [(2usize, 4usize), (3, 6)];
        let precisions = [Precision::F64, Precision::F32];
        // (kind, P): SGD ignores P; SMBGD pools are additionally split
        // by the lockstep mini-batch size.
        let forms =
            [(OptimizerKind::Sgd, 8usize), (OptimizerKind::Smbgd, 4), (OptimizerKind::Smbgd, 8)];
        let gs = [Nonlinearity::Cube, Nonlinearity::Tanh];

        let mut exec = CohortExecutor::<u64>::new(true);
        let mut hints: BTreeMap<u64, Option<CohortKey>> = BTreeMap::new();
        let mut id = 0u64;
        // Two copies of every eligible axis combination, so each pool
        // should come out exactly two lanes wide.
        for &(n, m) in &shapes {
            for &precision in &precisions {
                for &(kind, p) in &forms {
                    for &g in &gs {
                        for _copy in 0..2 {
                            let mut cfg = ExperimentConfig::default();
                            cfg.n = n;
                            cfg.m = m;
                            cfg.precision = precision;
                            cfg.optimizer.kind = kind;
                            cfg.optimizer.p = p;
                            cfg.optimizer.mu = 0.004;
                            let r = runner_with_g(&cfg, g);
                            exec.register(id, &r);
                            hints.insert(id, affinity_key(&cfg, g));
                            id += 1;
                        }
                    }
                }
            }
        }
        // Ineligible axes: fixed-point precision and the MBGD form have
        // no cohort kernel; both the hint and the live probe must agree
        // they stay per-session.
        let mut q16 = smbgd_cfg();
        q16.precision = Precision::Q16;
        let mut mbgd = sgd_cfg();
        mbgd.optimizer.kind = OptimizerKind::Mbgd;
        for cfg in [q16, mbgd] {
            let r = runner_with_g(&cfg, Nonlinearity::Cube);
            exec.register(id, &r);
            hints.insert(id, affinity_key(&cfg, Nonlinearity::Cube));
            id += 1;
        }

        let mut distinct: Vec<CohortKey> = Vec::new();
        for (&sid, hint) in &hints {
            match hint {
                None => assert!(!exec.is_member(sid), "ineligible session {sid} joined a pool"),
                Some(k) => {
                    let pi = *exec
                        .index
                        .get(&sid)
                        .unwrap_or_else(|| panic!("eligible session {sid} missing from a pool"));
                    assert_eq!(
                        exec.pools[pi].key, *k,
                        "session {sid}: live-probe pool key diverges from the placement hint"
                    );
                    if !distinct.contains(k) {
                        distinct.push(*k);
                    }
                }
            }
        }
        assert_eq!(exec.pool_count(), distinct.len(), "pools must partition exactly by key");
        for pool in &exec.pools {
            assert_eq!(pool.pending.len(), 2, "every axis combination was registered twice");
            for mid in pool.pending.keys() {
                assert_eq!(
                    hints[mid],
                    Some(pool.key),
                    "pool {:?} holds a session registered under different axes",
                    pool.key
                );
            }
        }
    }

    #[test]
    fn finish_session_flushes_the_surviving_peer() {
        let cfg = sgd_cfg();
        let mut runners: BTreeMap<u64, SessionRunner> = BTreeMap::new();
        let mut exec = CohortExecutor::<u64>::new(true);
        for id in 0..2u64 {
            let r = runner(&cfg);
            exec.register(id, &r);
            runners.insert(id, r);
        }
        // Only session 0 receives a block: its four chunks queue waiting
        // for session 1 (full-width policy, backlog under MAX_LAG).
        let b = blocks(42, 1, cfg.m).pop().unwrap();
        exec.on_block(0, b, &mut runners).unwrap();
        assert_eq!(runners.get(&0).unwrap().samples_done(), 0, "chunks queued, not applied");
        // Session 1 departs: the survivor must be drained so it reverts
        // to the per-session path fully caught up.
        exec.finish_session(1, &mut runners).unwrap();
        assert_eq!(runners.get(&0).unwrap().samples_done(), 256);
        assert!(exec.is_member(0), "survivor keeps membership for future peers");
    }

    /// Quarantine one lane of a 4-lane pool and pin every surviving
    /// lane's trajectory bitwise against an undisturbed 3-lane run: the
    /// mid-pump extraction must not perturb siblings (holds per
    /// precision and under the fma feature, where cohort == solo is
    /// already pinned).
    fn check_surviving_lanes(precision: Precision) {
        let mut cfg = sgd_cfg();
        cfg.precision = precision;
        let nan_block = |m: usize| Mat64::from_fn(256, m, |_, _| f64::NAN);

        // Disturbed run: four lanes, lane 3 fed non-finite data from the
        // first block — its guard latches after the retry budget and the
        // executor extracts it mid-pump.
        let mut runners: BTreeMap<u64, SessionRunner> = BTreeMap::new();
        let mut exec = CohortExecutor::<u64>::new(true);
        for id in 0..4u64 {
            let r = runner(&cfg);
            exec.register(id, &r);
            runners.insert(id, r);
        }
        for round in 0..4u64 {
            for id in 0..4u64 {
                if !runners.contains_key(&id) {
                    continue;
                }
                let b = if id == 3 {
                    nan_block(cfg.m)
                } else {
                    blocks(500 + id * 10 + round, 1, cfg.m).pop().unwrap()
                };
                exec.on_block(id, b, &mut runners).unwrap();
                for fid in exec.take_faulted() {
                    assert_eq!(fid, 3, "only the poisoned lane may fault");
                    assert!(!exec.is_member(fid), "extraction drops membership");
                    let r = runners.remove(&fid).unwrap();
                    assert!(
                        r.fault().unwrap().contains("rollback/reset attempts"),
                        "fault reason names the exhausted retry budget"
                    );
                }
            }
        }
        assert!(!runners.contains_key(&3), "poisoned lane was extracted");
        let mut disturbed = Vec::new();
        for id in 0..3u64 {
            exec.finish_session(id, &mut runners).unwrap();
            disturbed.push(runners.remove(&id).unwrap().finish());
        }

        // Undisturbed reference: the same three survivors, same data,
        // never sharing a pool with the poisoned lane.
        let mut ref_runners: BTreeMap<u64, SessionRunner> = BTreeMap::new();
        let mut ref_exec = CohortExecutor::<u64>::new(true);
        for id in 0..3u64 {
            let r = runner(&cfg);
            ref_exec.register(id, &r);
            ref_runners.insert(id, r);
        }
        for round in 0..4u64 {
            for id in 0..3u64 {
                let b = blocks(500 + id * 10 + round, 1, cfg.m).pop().unwrap();
                ref_exec.on_block(id, b, &mut ref_runners).unwrap();
            }
        }
        assert!(ref_exec.take_faulted().is_empty(), "clean lanes never fault");
        for (id, got) in disturbed.into_iter().enumerate() {
            ref_exec.finish_session(id as u64, &mut ref_runners).unwrap();
            let want = ref_runners.remove(&(id as u64)).unwrap().finish();
            assert_eq!(want.samples, got.samples, "lane {id}");
            assert!(
                want.b
                    .as_slice()
                    .iter()
                    .zip(got.b.as_slice())
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "lane {id}: quarantine extraction perturbed a survivor's B"
            );
        }
    }

    #[test]
    fn surviving_lanes_are_bitwise_unperturbed_f64() {
        check_surviving_lanes(Precision::F64);
    }

    #[test]
    fn surviving_lanes_are_bitwise_unperturbed_f32() {
        check_surviving_lanes(Precision::F32);
    }

    #[test]
    fn backlog_forces_partial_width_steps() {
        let cfg = sgd_cfg();
        let mut runners: BTreeMap<u64, SessionRunner> = BTreeMap::new();
        let mut exec = CohortExecutor::<u64>::new(true);
        for id in 0..2u64 {
            let r = runner(&cfg);
            exec.register(id, &r);
            runners.insert(id, r);
        }
        // Starve lane 1 while lane 0 keeps producing: once lane 0's
        // backlog hits MAX_LAG its chunks must step without the peer.
        for round in 0..3u64 {
            let b = blocks(900 + round, 1, cfg.m).pop().unwrap();
            exec.on_block(0, b, &mut runners).unwrap();
        }
        assert!(
            runners.get(&0).unwrap().samples_done() > 0,
            "MAX_LAG must bound a starved pool's latency"
        );
        assert_eq!(runners.get(&1).unwrap().samples_done(), 0);
    }

    #[test]
    fn last_lane_departure_drops_the_pool() {
        let cfg = sgd_cfg();
        let mut runners: BTreeMap<u64, SessionRunner> = BTreeMap::new();
        let mut exec = CohortExecutor::<u64>::new(true);
        for id in 0..2u64 {
            let r = runner(&cfg);
            exec.register(id, &r);
            runners.insert(id, r);
        }
        assert_eq!(exec.pool_count(), 1);
        // Feed a block so the pool has grown kernel state and scratch.
        let b = blocks(11, 1, cfg.m).pop().unwrap();
        exec.on_block(0, b, &mut runners).unwrap();
        exec.finish_session(0, &mut runners).unwrap();
        assert_eq!(exec.pool_count(), 1, "pool survives while a member remains");
        exec.finish_session(1, &mut runners).unwrap();
        assert_eq!(exec.pool_count(), 0, "zero-lane pool must be dropped, not parked");
        // The shape key readmits cleanly after the drop.
        let r = runner(&cfg);
        exec.register(5, &r);
        runners.insert(5, r);
        assert!(exec.is_member(5));
        assert_eq!(exec.pool_count(), 1);
    }

    /// Dropping a pool `swap_remove`s it, which renumbers the pool that
    /// filled the hole: the survivors' index entries must follow, and
    /// routing through the remapped pool must keep working.
    #[test]
    fn pool_drop_remaps_sibling_pool_index() {
        let sgd = sgd_cfg();
        let smbgd = smbgd_cfg();
        let mut runners: BTreeMap<u64, SessionRunner> = BTreeMap::new();
        let mut exec = CohortExecutor::<u64>::new(true);
        // ids 0,1 → SGD pool (index 0); ids 2,3 → SMBGD pool (index 1).
        for id in 0..4u64 {
            let r = runner(if id < 2 { &sgd } else { &smbgd });
            exec.register(id, &r);
            runners.insert(id, r);
        }
        assert_eq!(exec.pool_count(), 2);
        exec.finish_session(0, &mut runners).unwrap();
        exec.finish_session(1, &mut runners).unwrap();
        assert_eq!(exec.pool_count(), 1, "emptied SGD pool dropped");
        assert_eq!(exec.pool_width(2), Some(2), "survivor pool remapped, width intact");
        // Routing still lands in the remapped pool: a full-width round
        // steps both SMBGD lanes.
        for id in 2..4u64 {
            let b = blocks(60 + id, 1, sgd.m).pop().unwrap();
            exec.on_block(id, b, &mut runners).unwrap();
        }
        assert_eq!(runners.get(&2).unwrap().samples_done(), 256);
        assert_eq!(runners.get(&3).unwrap().samples_done(), 256);
    }

    mod alloc_track {
        use std::alloc::{GlobalAlloc, Layout, System};
        use std::cell::Cell;

        thread_local! {
            static LIVE: Cell<i64> = const { Cell::new(0) };
        }

        /// Passthrough allocator tracking *net* live bytes per thread
        /// (must not itself allocate: const-initialized TLS, `try_with`
        /// for teardown).
        struct NetAllocator;

        unsafe impl GlobalAlloc for NetAllocator {
            unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
                let _ = LIVE.try_with(|c| c.set(c.get() + layout.size() as i64));
                System.alloc(layout)
            }
            unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
                let _ = LIVE.try_with(|c| c.set(c.get() + layout.size() as i64));
                System.alloc_zeroed(layout)
            }
            unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
                let _ =
                    LIVE.try_with(|c| c.set(c.get() + new_size as i64 - layout.size() as i64));
                System.realloc(ptr, layout, new_size)
            }
            unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
                let _ = LIVE.try_with(|c| c.set(c.get() - layout.size() as i64));
                System.dealloc(ptr, layout)
            }
        }

        #[global_allocator]
        static ALLOCATOR: NetAllocator = NetAllocator;

        /// Net heap bytes retained by `f` on this thread.
        pub(super) fn net_bytes_in(f: impl FnOnce()) -> i64 {
            let before = LIVE.with(|c| c.get());
            f();
            LIVE.with(|c| c.get()) - before
        }
    }

    /// The regression the empty-pool drop fixes: before it, every
    /// admit-run-finish cycle under a fresh shape key parked another
    /// zero-lane pool (kernel state + scratch) forever. With the fix,
    /// steady-state churn retains not a single net heap byte.
    #[test]
    fn empty_pool_drop_keeps_churn_net_allocation_free() {
        let sgd = sgd_cfg();
        let smbgd = smbgd_cfg();
        let mut exec = CohortExecutor::<u64>::new(true);

        let cycle = |exec: &mut CohortExecutor<u64>, seed: u64| {
            let mut runners: BTreeMap<u64, SessionRunner> = BTreeMap::new();
            for id in 0..4u64 {
                let r = runner(if id < 2 { &sgd } else { &smbgd });
                exec.register(id, &r);
                runners.insert(id, r);
            }
            assert_eq!(exec.pool_count(), 2);
            for id in 0..4u64 {
                let b = blocks(seed + id, 1, sgd.m).pop().unwrap();
                exec.on_block(id, b, &mut runners).unwrap();
            }
            for id in 0..4u64 {
                exec.finish_session(id, &mut runners).unwrap();
                runners.remove(&id).unwrap().finish();
            }
            assert_eq!(exec.pool_count(), 0, "churned-out pools must be dropped");
        };

        // Warm: the first cycle grows the executor's reusable vectors and
        // any lazily initialized process state.
        cycle(&mut exec, 1000);
        let net = alloc_track::net_bytes_in(|| {
            for k in 0..8u64 {
                cycle(&mut exec, 2000 + 10 * k);
            }
        });
        assert_eq!(net, 0, "admission churn retained pool memory (stale zero-lane pools?)");
    }
}
