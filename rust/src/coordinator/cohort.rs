//! Cohort executor: tenant-major batching for the worker hot loop.
//!
//! Both hub flavours used to step one session's chunk at a time — for the
//! millions-of-small-tenants regime that wastes the worker on per-session
//! loop setup and dispatch instead of flops. The [`CohortExecutor`] sits
//! between a shard's event loop and its [`SessionRunner`]s and regroups
//! the work *tenant-major*: sessions with the same shape key
//! (`n`, `m`, chunk size, nonlinearity, precision) form a *pool*, and one
//! pool step advances every ready member through a single
//! [`CohortState`] kernel whose inner loops run across the tenants.
//!
//! ## Ordering and bit-identity
//!
//! Cohort execution is a pure re-scheduling: each session's event
//! sequence (chunk applied → bookkeeping → mixing snapshot → …) is
//! exactly the per-session order — only *when* a chunk runs relative to
//! other sessions' chunks changes, and sessions are independent. Combined
//! with the per-lane bit-identity of the [`CohortState`] kernels, a
//! session's trajectory (B bits, Amari history, reset/drift counters) is
//! identical with the executor on or off, under every build. Pinned by
//! `tests/integration_cohort.rs`.
//!
//! Each pool step reloads every lane's `(B, μ)` from its engine, so
//! divergence-guard resets and the adaptive governor's μ retunes feed
//! back into the very next step, exactly as on the per-session path.
//!
//! ## Membership lifecycle
//!
//! - `register` at admission: eligible sessions (plain fused EASI-SGD
//!   native engines — [`SessionRunner::cohort_lane`]) join the pool for
//!   their shape key; everything else stays on the per-session path.
//! - A member without peers (pool of one) is routed straight through
//!   `SessionRunner::on_block` — the fall-back the issue requires — and
//!   its queue is kept empty so there is nothing to extract.
//! - `finish_session` (End, park, detach) drains the member's queued
//!   items in order through the ordinary per-session path and removes it:
//!   the runner is self-contained again, so the PR-5 park/reattach
//!   bit-identity pins hold unchanged. If the pool drops to one member,
//!   the survivor's queue is drained too (it reverts to the direct path).
//! - `flush_session` (checkpoint/restore) drains without removing, so a
//!   `Restore`'s `install_b` lands on a fully caught-up runner.
//!
//! ## Batching policy
//!
//! Chunks queue per lane; a pool steps when every member has a chunk
//! ready (full-width step) or when any member's backlog reaches
//! [`MAX_LAG`] items (then the ready subset steps, bounding latency and
//! memory when producers run at different speeds or a member idles).

use super::server::SessionRunner;
use crate::config::Precision;
use crate::ica::nonlinearity::{with_g, Nonlinearity};
use crate::linalg::{CohortState, Mat64, Scalar};
use anyhow::Result;
use std::collections::{BTreeMap, VecDeque};

/// Backlog bound (queued items per lane) that forces a partial-width pool
/// step. One producer block is four chunks at the default chunk size, so
/// 8 keeps at most two blocks buffered per lane.
const MAX_LAG: usize = 8;

/// Shape key pooling compatible tenants: lanes must agree on the matrix
/// shape (one SoA block), the chunk size (lockstep rows), the
/// nonlinearity (one monomorphized kernel) and the precision (one scalar
/// type).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct CohortKey {
    n: usize,
    m: usize,
    chunk: usize,
    g: Nonlinearity,
    precision: Precision,
}

/// One queued per-lane event, preserving the session's event order: a
/// mixing snapshot queued behind a chunk is applied only after that
/// chunk's bookkeeping, exactly as on the per-session path.
enum LaneItem {
    Chunk(Mat64),
    Mixing(Mat64),
}

/// The pool's kernel state, monomorphized per precision.
enum PoolState {
    F64(CohortState<f64>),
    F32(CohortState<f32>),
}

/// One shape-key pool: member queues plus reusable step scratch.
struct Pool<K: Ord + Copy> {
    key: CohortKey,
    state: PoolState,
    /// Per-member FIFO, keyed by session id — `BTreeMap` so lane order
    /// within a step is deterministic (ascending id).
    pending: BTreeMap<K, VecDeque<LaneItem>>,
    /// Scratch: ids stepping this round (reused across steps).
    ready: Vec<K>,
    /// Scratch: the chunks popped for this step, lane-ordered.
    chunks: Vec<Mat64>,
    /// Scratch: completed chunks from one block ingest.
    ingested: Vec<Mat64>,
    /// Scratch: per-lane B staging for store/sync (grown once).
    bs: Vec<Mat64>,
}

impl<K: Ord + Copy> Pool<K> {
    fn new(key: CohortKey) -> Self {
        let state = match key.precision {
            Precision::F64 => PoolState::F64(CohortState::new(key.n, key.m)),
            Precision::F32 => PoolState::F32(CohortState::new(key.n, key.m)),
        };
        Self {
            key,
            state,
            pending: BTreeMap::new(),
            ready: Vec::new(),
            chunks: Vec::new(),
            ingested: Vec::new(),
            bs: Vec::new(),
        }
    }
}

/// Dispatch the nonlinearity once per pool step (the same `with_g!` seam
/// the per-session optimizer uses, so the monomorphized closures match).
fn step_pool_state<T: Scalar>(st: &mut CohortState<T>, g: Nonlinearity, chunks: &[Mat64]) {
    with_g!(T, g, gf => st.step_chunks(gf, chunks));
}

/// Drain one lane's queue in order through the per-session path.
fn drain_lane(q: &mut VecDeque<LaneItem>, runner: &mut SessionRunner) -> Result<()> {
    while let Some(item) = q.pop_front() {
        match item {
            LaneItem::Chunk(c) => runner.apply_chunk(&c)?,
            LaneItem::Mixing(a) => runner.on_mixing(a),
        }
    }
    Ok(())
}

/// Run pool steps until the batching policy says wait: apply front-of-
/// queue mixing snapshots, then step every ready lane through the fused
/// cohort kernel and feed the results back into the runners.
fn pump<K: Ord + Copy>(
    pool: &mut Pool<K>,
    runners: &mut BTreeMap<K, SessionRunner>,
) -> Result<()> {
    loop {
        // Front-of-queue mixing snapshots are ready to apply: everything
        // ordered before them has been stepped and noted.
        for (id, q) in pool.pending.iter_mut() {
            while matches!(q.front(), Some(LaneItem::Mixing(_))) {
                if let Some(LaneItem::Mixing(a)) = q.pop_front() {
                    if let Some(r) = runners.get_mut(id) {
                        r.on_mixing(a);
                    }
                }
            }
        }
        pool.ready.clear();
        let mut max_backlog = 0;
        for (id, q) in pool.pending.iter() {
            if matches!(q.front(), Some(LaneItem::Chunk(_))) {
                pool.ready.push(*id);
            }
            max_backlog = max_backlog.max(q.len());
        }
        if pool.ready.is_empty() {
            return Ok(());
        }
        // Prefer full-width steps; break lockstep only when a lane's
        // backlog says waiting costs latency/memory.
        if pool.ready.len() < pool.pending.len() && max_backlog < MAX_LAG {
            return Ok(());
        }

        let lanes = pool.ready.len();
        pool.chunks.clear();
        for id in pool.ready.iter() {
            match pool.pending.get_mut(id).and_then(VecDeque::pop_front) {
                Some(LaneItem::Chunk(c)) => pool.chunks.push(c),
                _ => unreachable!("ready lane must front a chunk"),
            }
        }
        while pool.bs.len() < lanes {
            pool.bs.push(Mat64::zeros(pool.key.n, pool.key.m));
        }
        match &mut pool.state {
            PoolState::F64(st) => {
                step_loaded(st, pool.key.g, &pool.ready, &pool.chunks, &mut pool.bs, runners)?;
            }
            PoolState::F32(st) => {
                step_loaded(st, pool.key.g, &pool.ready, &pool.chunks, &mut pool.bs, runners)?;
            }
        }
    }
}

/// One pool step at a fixed precision: load every ready lane's `(B, μ)`
/// fresh from its engine, run the fused cohort kernel, then store each
/// lane back and run its per-chunk bookkeeping — the exact
/// `submit_chunk` → bookkeeping sequence of the per-session path, per
/// lane, in ascending session-id order.
fn step_loaded<T: Scalar, K: Ord + Copy>(
    st: &mut CohortState<T>,
    g: Nonlinearity,
    ready: &[K],
    chunks: &[Mat64],
    bs: &mut [Mat64],
    runners: &mut BTreeMap<K, SessionRunner>,
) -> Result<()> {
    st.begin(ready.len());
    for (l, id) in ready.iter().enumerate() {
        let r = runners.get(id).expect("cohort member has a runner");
        let lane = r.cohort_lane().expect("cohort member kept its lane");
        st.load_lane(l, &r.cohort_b(), lane.mu);
    }
    step_pool_state(st, g, chunks);
    for (l, id) in ready.iter().enumerate() {
        st.store_lane(l, &mut bs[l]);
        let r = runners.get_mut(id).expect("cohort member has a runner");
        r.cohort_sync(&bs[l], chunks[l].rows() as u64);
        r.note_cohort_chunk(&chunks[l]);
    }
    Ok(())
}

/// Per-shard cohort scheduler: owns the pools and routes each session
/// event either through a cohort pool or straight to the session's
/// runner. Generic over the shard's session-id key (`usize` in the batch
/// hub, `u64` in the elastic hub).
pub(crate) struct CohortExecutor<K: Ord + Copy = u64> {
    enabled: bool,
    pools: Vec<Pool<K>>,
    /// Members only: session id → pool index.
    index: BTreeMap<K, usize>,
}

impl<K: Ord + Copy> CohortExecutor<K> {
    pub(crate) fn new(enabled: bool) -> Self {
        Self { enabled, pools: Vec::new(), index: BTreeMap::new() }
    }

    /// Admit a session: eligible runners (cohort-capable engines) join
    /// the pool for their shape key; the rest stay on the per-session
    /// path. Idempotent per id.
    pub(crate) fn register(&mut self, id: K, runner: &SessionRunner) {
        if !self.enabled || self.index.contains_key(&id) {
            return;
        }
        let Some(lane) = runner.cohort_lane() else { return };
        let (n, m) = runner.shape();
        let key = CohortKey {
            n,
            m,
            chunk: runner.chunk_size(),
            g: lane.g,
            precision: lane.precision,
        };
        let pi = match self.pools.iter().position(|p| p.key == key) {
            Some(pi) => pi,
            None => {
                self.pools.push(Pool::new(key));
                self.pools.len() - 1
            }
        };
        self.pools[pi].pending.insert(id, VecDeque::new());
        self.index.insert(id, pi);
    }

    /// Whether a session currently runs as a cohort lane (tests).
    #[cfg(test)]
    pub(crate) fn is_member(&self, id: K) -> bool {
        self.index.contains_key(&id)
    }

    /// Route one producer block: members with peers ingest (AGC + chunk)
    /// into their lane queue and the pool pumps; everyone else takes the
    /// unchanged per-session path.
    pub(crate) fn on_block(
        &mut self,
        id: K,
        block: Mat64,
        runners: &mut BTreeMap<K, SessionRunner>,
    ) -> Result<()> {
        if let Some(&pi) = self.index.get(&id) {
            let pool = &mut self.pools[pi];
            if pool.pending.len() >= 2 {
                let runner = runners.get_mut(&id).expect("cohort member has a runner");
                pool.ingested.clear();
                runner.ingest_block_into(block, &mut pool.ingested);
                let q = pool.pending.get_mut(&id).expect("member has a lane queue");
                for c in pool.ingested.drain(..) {
                    q.push_back(LaneItem::Chunk(c));
                }
                return pump(pool, runners);
            }
            // Member without shape peers: per-session path, unchanged
            // (its queue is empty by the membership invariants).
        }
        runners.get_mut(&id).expect("session has a runner").on_block(block)
    }

    /// Route one mixing snapshot: queued behind any pending chunks so the
    /// lane's event order is preserved; applied directly when nothing is
    /// queued (which is exactly the per-session timing).
    pub(crate) fn on_mixing(
        &mut self,
        id: K,
        a: Mat64,
        runners: &mut BTreeMap<K, SessionRunner>,
    ) {
        if let Some(&pi) = self.index.get(&id) {
            let pool = &mut self.pools[pi];
            if pool.pending.len() >= 2 {
                let q = pool.pending.get_mut(&id).expect("member has a lane queue");
                if !q.is_empty() {
                    q.push_back(LaneItem::Mixing(a));
                    return;
                }
            }
        }
        if let Some(r) = runners.get_mut(&id) {
            r.on_mixing(a);
        }
    }

    /// Catch a member's runner up with everything queued for it (in
    /// order, through the per-session path) without changing membership —
    /// the checkpoint/restore hook: after this, the runner is exactly the
    /// session's per-session state.
    pub(crate) fn flush_session(
        &mut self,
        id: K,
        runners: &mut BTreeMap<K, SessionRunner>,
    ) -> Result<()> {
        if let Some(&pi) = self.index.get(&id) {
            if let Some(q) = self.pools[pi].pending.get_mut(&id) {
                if let Some(runner) = runners.get_mut(&id) {
                    drain_lane(q, runner)?;
                }
            }
        }
        Ok(())
    }

    /// Extract a session from its pool (End / park / detach): drain its
    /// queue so the runner is self-contained, then drop membership. A
    /// pool left with a single member has that survivor drained too — it
    /// reverts to the per-session path.
    pub(crate) fn finish_session(
        &mut self,
        id: K,
        runners: &mut BTreeMap<K, SessionRunner>,
    ) -> Result<()> {
        let Some(&pi) = self.index.get(&id) else { return Ok(()) };
        self.flush_session(id, runners)?;
        self.index.remove(&id);
        let pool = &mut self.pools[pi];
        pool.pending.remove(&id);
        if pool.pending.len() == 1 {
            let (&sid, q) = pool.pending.iter_mut().next().expect("len checked");
            if let Some(r) = runners.get_mut(&sid) {
                drain_lane(q, r)?;
            }
        }
        Ok(())
    }

    /// Drain every queue (shutdown / producer-disconnect path) so the
    /// shard's leftover runners can be finished per-session.
    pub(crate) fn flush_all(&mut self, runners: &mut BTreeMap<K, SessionRunner>) -> Result<()> {
        for pool in self.pools.iter_mut() {
            for (id, q) in pool.pending.iter_mut() {
                if let Some(r) = runners.get_mut(id) {
                    drain_lane(q, r)?;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, OptimizerKind};
    use crate::coordinator::engine::make_engine;
    use crate::coordinator::server::{ServerOptions, SessionRunner};
    use crate::coordinator::state::StateStore;
    use crate::signal::Pcg32;

    fn sgd_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.optimizer.kind = OptimizerKind::Sgd;
        cfg.optimizer.mu = 0.004;
        cfg
    }

    fn runner(cfg: &ExperimentConfig) -> SessionRunner {
        let engine = make_engine(cfg, Nonlinearity::Cube).unwrap();
        let state = StateStore::new(crate::ica::init_b(cfg.n, cfg.m));
        SessionRunner::new(cfg, engine, &ServerOptions::default(), state)
    }

    fn blocks(seed: u64, count: usize, m: usize) -> Vec<Mat64> {
        let mut rng = Pcg32::seed(seed);
        (0..count).map(|_| Mat64::from_fn(256, m, |_, _| rng.normal())).collect()
    }

    #[test]
    fn cohort_routing_matches_solo_runners_bitwise() {
        let cfg = sgd_cfg();
        let a = Mat64::eye(cfg.m, cfg.n);
        // Three same-shape sessions through the executor…
        let mut runners: BTreeMap<u64, SessionRunner> = BTreeMap::new();
        let mut exec = CohortExecutor::<u64>::new(true);
        for id in 0..3u64 {
            let r = runner(&cfg);
            exec.register(id, &r);
            runners.insert(id, r);
        }
        assert!(exec.is_member(0) && exec.is_member(2));
        for id in 0..3u64 {
            exec.on_mixing(id, a.clone(), &mut runners);
        }
        for round in 0..4 {
            for id in 0..3u64 {
                let b = blocks(100 + id * 10 + round, 1, cfg.m).pop().unwrap();
                exec.on_block(id, b, &mut runners).unwrap();
                exec.on_mixing(id, a.clone(), &mut runners);
            }
        }
        let mut cohort_bs = Vec::new();
        for id in 0..3u64 {
            exec.finish_session(id, &mut runners).unwrap();
            cohort_bs.push(runners.remove(&id).unwrap().finish());
        }
        // …against the same sessions run solo.
        for (id, got) in cohort_bs.into_iter().enumerate() {
            let mut solo = runner(&cfg);
            solo.on_mixing(a.clone());
            for round in 0..4 {
                let b = blocks(100 + id as u64 * 10 + round, 1, cfg.m).pop().unwrap();
                solo.on_block(b).unwrap();
                solo.on_mixing(a.clone());
            }
            let want = solo.finish();
            assert_eq!(want.samples, got.samples, "session {id}");
            assert_eq!(want.tail_dropped, got.tail_dropped, "session {id}");
            assert!(
                want.b
                    .as_slice()
                    .iter()
                    .zip(got.b.as_slice())
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "session {id}: cohort B diverged from solo B"
            );
            assert_eq!(want.amari_history.len(), got.amari_history.len());
        }
    }

    #[test]
    fn lone_member_and_ineligible_sessions_take_the_solo_path() {
        let cfg = sgd_cfg();
        let mut smbgd_cfg = cfg.clone();
        smbgd_cfg.optimizer.kind = OptimizerKind::Smbgd;

        let mut runners: BTreeMap<u64, SessionRunner> = BTreeMap::new();
        let mut exec = CohortExecutor::<u64>::new(true);
        let r0 = runner(&cfg);
        let r1 = runner(&smbgd_cfg);
        exec.register(0, &r0);
        exec.register(1, &r1);
        runners.insert(0, r0);
        runners.insert(1, r1);
        assert!(exec.is_member(0), "plain SGD is cohort-capable");
        assert!(!exec.is_member(1), "SMBGD must stay per-session");

        // A member without shape peers routes straight through; its
        // samples land immediately (nothing queued).
        let b = blocks(7, 1, cfg.m).pop().unwrap();
        exec.on_block(0, b, &mut runners).unwrap();
        assert_eq!(runners.get(&0).unwrap().samples_done(), 256);
        let b = blocks(8, 1, cfg.m).pop().unwrap();
        exec.on_block(1, b, &mut runners).unwrap();
        assert!(runners.get(&1).unwrap().samples_done() > 0);
    }

    #[test]
    fn disabled_executor_registers_nobody() {
        let cfg = sgd_cfg();
        let mut exec = CohortExecutor::<u64>::new(false);
        let r = runner(&cfg);
        exec.register(0, &r);
        assert!(!exec.is_member(0));
    }

    #[test]
    fn finish_session_flushes_the_surviving_peer() {
        let cfg = sgd_cfg();
        let mut runners: BTreeMap<u64, SessionRunner> = BTreeMap::new();
        let mut exec = CohortExecutor::<u64>::new(true);
        for id in 0..2u64 {
            let r = runner(&cfg);
            exec.register(id, &r);
            runners.insert(id, r);
        }
        // Only session 0 receives a block: its four chunks queue waiting
        // for session 1 (full-width policy, backlog under MAX_LAG).
        let b = blocks(42, 1, cfg.m).pop().unwrap();
        exec.on_block(0, b, &mut runners).unwrap();
        assert_eq!(runners.get(&0).unwrap().samples_done(), 0, "chunks queued, not applied");
        // Session 1 departs: the survivor must be drained so it reverts
        // to the per-session path fully caught up.
        exec.finish_session(1, &mut runners).unwrap();
        assert_eq!(runners.get(&0).unwrap().samples_done(), 256);
        assert!(exec.is_member(0), "survivor keeps membership for future peers");
    }

    #[test]
    fn backlog_forces_partial_width_steps() {
        let cfg = sgd_cfg();
        let mut runners: BTreeMap<u64, SessionRunner> = BTreeMap::new();
        let mut exec = CohortExecutor::<u64>::new(true);
        for id in 0..2u64 {
            let r = runner(&cfg);
            exec.register(id, &r);
            runners.insert(id, r);
        }
        // Starve lane 1 while lane 0 keeps producing: once lane 0's
        // backlog hits MAX_LAG its chunks must step without the peer.
        for round in 0..3u64 {
            let b = blocks(900 + round, 1, cfg.m).pop().unwrap();
            exec.on_block(0, b, &mut runners).unwrap();
        }
        assert!(
            runners.get(&0).unwrap().samples_done() > 0,
            "MAX_LAG must bound a starved pool's latency"
        );
        assert_eq!(runners.get(&1).unwrap().samples_done(), 0);
    }
}
