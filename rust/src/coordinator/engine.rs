//! Execution engines: the pluggable back-ends that apply separation-matrix
//! updates for a chunk of samples.
//!
//! Two engines implement the same contract and are pinned together by
//! parity tests (`rust/tests/parity_pjrt.rs`):
//!
//! - [`NativeEngine`] — the pure-Rust `ica::Optimizer` hot path (per-sample
//!   loop, models the FPGA sample-per-clock pipeline).
//! - [`PjrtEngine`] — executes the AOT-compiled JAX/Pallas chunk programs
//!   via PJRT (the "TPU deployment" path; no Python at runtime).

use crate::config::{EngineKind, ExperimentConfig, OptimizerKind, Precision};
use crate::ica::{self, Nonlinearity, Optimizer};
use crate::linalg::{Mat, Mat64, Scalar};
use crate::runtime::{PjrtRuntime, ProgramKind};
use anyhow::{bail, Context, Result};

/// Per-tenant lane descriptor for cohort execution: what a
/// cohort-capable engine's chunk submission actually computes, exposed so
/// the executor can key same-shape tenants together and reload each
/// lane's `(B, μ)` fresh every pool step (the adaptive governor may have
/// retuned μ between steps).
#[derive(Clone, Copy, Debug)]
pub struct CohortLane {
    /// Current learning rate (f64 hyperparameter space; lanes narrow it
    /// exactly like the per-session step does).
    pub mu: f64,
    /// The nonlinearity the lane's fused kernel must apply.
    pub g: Nonlinearity,
    /// Arithmetic precision of the lane (part of the cohort shape key —
    /// mixing precisions in one SoA block is impossible).
    pub precision: Precision,
    /// Which kernel family the lane runs (part of the pool key: SGD and
    /// SMBGD lanes cannot share an SoA block).
    pub form: CohortLaneForm,
}

/// The kernel family of a cohort lane. The pool key folds in only the
/// *structural* parameters (the mini-batch size P, which fixes the shared
/// loop shape); per-lane coefficients (μ, γ, β) ride as lane data so
/// tenants with different hyperparameters still pool together.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CohortLaneForm {
    /// Plain fused EASI-SGD per-sample loop.
    Sgd,
    /// Plain SMBGD fused block path at a batch boundary.
    Smbgd {
        /// Mini-batch size P (structural — keys the pool).
        p: usize,
        /// Cross-batch momentum γ (per-lane data).
        gamma: f64,
        /// Intra-batch decay β (per-lane data).
        beta: f64,
    },
}

/// A chunk-oriented executor of EASI updates.
///
/// `Send` so the hub can move per-session engines onto worker shards.
pub trait Engine: Send {
    /// Preferred chunk size in samples. [`NativeEngine`] accepts any
    /// chunk; [`PjrtEngine`] requires exactly this many rows per submit.
    fn chunk_size(&self) -> usize;
    /// Apply updates for a row-major `len × m` chunk of samples.
    fn submit_chunk(&mut self, xs: &Mat64) -> Result<()>;
    /// Snapshot of the current separation matrix (n × m).
    fn b(&self) -> Mat64;
    /// Samples consumed so far.
    fn samples_done(&self) -> u64;
    /// Description for logs/reports.
    fn describe(&self) -> String;
    /// Install a fresh separation matrix (divergence recovery).
    fn reset_b(&mut self, b: Mat64);
    /// Install a new learning rate μ (the adaptive control plane's
    /// actuator; takes effect from the next submitted chunk).
    fn set_mu(&mut self, mu: f64);

    /// Cumulative count of fixed-point saturation-latch events this
    /// engine's kernels have recorded (rail clamps and non-finite
    /// quantizations in `qfx` arithmetic). Always zero for floating-point
    /// engines; the serving plane uses the per-chunk delta as the
    /// fixed-point divergence guard (a Q-format value is never NaN, so
    /// the non-finite check can't fire for these tenants).
    fn saturation_events(&self) -> u64 {
        0
    }

    /// Cohort-execution probe: `Some` iff one `submit_chunk` on this
    /// engine is *exactly* the plain fused EASI-SGD per-sample loop at
    /// the reported precision, so a [`crate::linalg::CohortState`] lane
    /// loaded from `b()`/`mu` reproduces it bit-for-bit. PJRT and the
    /// mini-batch/normalized optimizers return `None` (the default) and
    /// stay on the per-session path.
    fn cohort_lane(&self) -> Option<CohortLane> {
        None
    }

    /// Install the cohort-stepped separation matrix and account the
    /// `rows` samples the cohort kernel consumed on this engine's behalf.
    /// Only ever called on engines that returned `Some` from
    /// [`cohort_lane`](Self::cohort_lane).
    fn cohort_sync(&mut self, _b: &Mat64, _rows: u64) {
        unreachable!("cohort_sync on an engine that did not offer a cohort lane");
    }

    /// The SMBGD cross-batch accumulator `Ĥ_prev` (f64 wire format) for
    /// loading into a cohort lane. Only ever called on engines whose
    /// [`cohort_lane`](Self::cohort_lane) reported
    /// [`CohortLaneForm::Smbgd`].
    fn cohort_hhat_prev(&self) -> Mat64 {
        unreachable!("cohort_hhat_prev on an engine that did not offer an SMBGD lane");
    }

    /// Install the SMBGD cohort step's output — `B`, the latched
    /// `Ĥ_prev`, and the `rows` samples (whole mini-batches) consumed.
    /// Only ever called on engines whose
    /// [`cohort_lane`](Self::cohort_lane) reported
    /// [`CohortLaneForm::Smbgd`].
    fn cohort_sync_smbgd(&mut self, _b: &Mat64, _hhat_prev: &Mat64, _rows: u64) {
        unreachable!("cohort_sync_smbgd on an engine that did not offer an SMBGD lane");
    }

    /// Serialize the engine's full learning state for detach-to-disk.
    /// Contract with [`load_state`](Self::load_state): a freshly built
    /// engine (same config) that loads this state continues
    /// **bit-identically**. Default: error — engines without a durability
    /// story (PJRT holds device-side program state) refuse explicitly.
    fn save_state(&self, _w: &mut crate::snapshot::SnapWriter) -> Result<()> {
        bail!("engine '{}' does not support detach-to-disk", self.describe())
    }

    /// Rehydrate the state written by [`save_state`](Self::save_state)
    /// into a freshly constructed engine of the same configuration.
    fn load_state(&mut self, _r: &mut crate::snapshot::SnapReader<'_>) -> Result<()> {
        bail!("engine '{}' does not support detach-to-disk", self.describe())
    }
}

/// Chunk size for the native engines, shared across precisions: aligned
/// with the optimizer's mini-batch so state snapshots land on batch
/// boundaries. `pub(crate)` so the hub's shape-aware placement can
/// mirror the pool key a config would produce without building an
/// engine.
pub(crate) fn native_chunk_size(cfg: &ExperimentConfig) -> usize {
    match cfg.optimizer.kind {
        OptimizerKind::Sgd => 64,
        _ => cfg.optimizer.p.max(1) * 8,
    }
}

/// Pure-Rust engine wrapping any [`ica::Optimizer`].
pub struct NativeEngine {
    opt: Box<dyn Optimizer>,
    chunk: usize,
}

impl NativeEngine {
    pub fn new(opt: Box<dyn Optimizer>, chunk: usize) -> Self {
        assert!(chunk >= 1);
        Self { opt, chunk }
    }

    /// Build from an experiment config with the standard warm start.
    pub fn from_config(cfg: &ExperimentConfig, g: Nonlinearity) -> Self {
        let opt = ica::make_optimizer(&cfg.optimizer, cfg.n, cfg.m, g);
        Self::new(opt, native_chunk_size(cfg))
    }

    /// Access the wrapped optimizer (tests).
    pub fn optimizer(&self) -> &dyn Optimizer {
        self.opt.as_ref()
    }
}

impl Engine for NativeEngine {
    fn chunk_size(&self) -> usize {
        self.chunk
    }

    fn submit_chunk(&mut self, xs: &Mat64) -> Result<()> {
        self.opt.step_batch(xs);
        Ok(())
    }

    fn b(&self) -> Mat64 {
        self.opt.b().clone()
    }

    fn samples_done(&self) -> u64 {
        self.opt.samples_seen()
    }

    fn describe(&self) -> String {
        format!("native/{}", self.opt.name())
    }

    fn reset_b(&mut self, b: Mat64) {
        self.opt.b_mut().copy_from(&b);
    }

    fn set_mu(&mut self, mu: f64) {
        self.opt.set_mu(mu);
    }

    fn cohort_lane(&self) -> Option<CohortLane> {
        cohort_lane_for(self.opt.as_ref(), Precision::F64)
    }

    fn cohort_sync(&mut self, b: &Mat64, rows: u64) {
        self.opt.b_mut().copy_from(b);
        self.opt.note_cohort_rows(rows);
    }

    fn cohort_hhat_prev(&self) -> Mat64 {
        self.opt.cohort_hhat_prev()
    }

    fn cohort_sync_smbgd(&mut self, b: &Mat64, hhat_prev: &Mat64, rows: u64) {
        self.opt.cohort_sync_smbgd(b, hhat_prev, rows);
    }

    fn save_state(&self, w: &mut crate::snapshot::SnapWriter) -> Result<()> {
        self.opt.save_state(w)
    }

    fn load_state(&mut self, r: &mut crate::snapshot::SnapReader<'_>) -> Result<()> {
        self.opt.load_state(r)
    }
}

/// Shared cohort-lane probe for the native engines: plain SGD first (the
/// phase-1 form), then plain SMBGD at a batch boundary (phase 2). Every
/// other optimizer state keeps the per-session path.
fn cohort_lane_for<T: Scalar>(opt: &dyn Optimizer<T>, precision: Precision) -> Option<CohortLane> {
    if let Some((mu, g)) = opt.cohort_plain() {
        return Some(CohortLane { mu, g, precision, form: CohortLaneForm::Sgd });
    }
    opt.cohort_smbgd().map(|(prm, g)| CohortLane {
        mu: prm.mu,
        g,
        precision,
        form: CohortLaneForm::Smbgd { p: prm.p, gamma: prm.gamma, beta: prm.beta },
    })
}

/// Precision-generic native engine: the whole optimizer state machine —
/// gradient, accumulator, separation matrix — runs in `T` (the paper's
/// hardware is `T = f32`), while the coordinator's wire format stays
/// `f64`: each ingest chunk is narrowed once into a reusable buffer on
/// submit and `B` is widened on snapshot. The `f64` wire keeps the
/// producer/AGC/monitor stack precision-agnostic, so one hub can serve
/// `f32` and `f64` tenants side by side.
///
/// `CastNativeEngine<f64>` would be a plain copy of [`NativeEngine`];
/// that type therefore stays the dedicated f64 path (no narrowing work,
/// bit-exact by construction) and this one serves every other precision.
pub struct CastNativeEngine<T: Scalar> {
    opt: Box<dyn Optimizer<T>>,
    chunk: usize,
    /// Reusable narrowed-chunk buffer (chunk_size × m on the steady path;
    /// reshaped only if a caller submits an odd-sized chunk).
    xs_t: Mat<T>,
    /// Cumulative `qfx` saturation-latch events attributed to this
    /// engine's submits (always 0 for float `T`). Transient telemetry —
    /// deliberately not part of the detach-to-disk state.
    sat_events: u64,
}

impl<T: Scalar> CastNativeEngine<T> {
    pub fn new(opt: Box<dyn Optimizer<T>>, chunk: usize) -> Self {
        assert!(chunk >= 1);
        let (_, m) = opt.b().shape();
        Self { xs_t: Mat::zeros(chunk, m), opt, chunk, sat_events: 0 }
    }

    /// Build from an experiment config with the standard warm start
    /// (same [`native_chunk_size`] policy as [`NativeEngine::from_config`],
    /// so f32 and f64 sessions snapshot on identical boundaries).
    pub fn from_config(cfg: &ExperimentConfig, g: Nonlinearity) -> Self {
        let opt = ica::make_optimizer_t::<T>(&cfg.optimizer, cfg.n, cfg.m, g);
        Self::new(opt, native_chunk_size(cfg))
    }

    /// Access the wrapped optimizer (tests).
    pub fn optimizer(&self) -> &dyn Optimizer<T> {
        self.opt.as_ref()
    }
}

impl<T: Scalar> Engine for CastNativeEngine<T> {
    fn chunk_size(&self) -> usize {
        self.chunk
    }

    fn submit_chunk(&mut self, xs: &Mat64) -> Result<()> {
        if self.xs_t.shape() != xs.shape() {
            // Odd-sized chunk (never on the Chunker's steady path).
            self.xs_t = Mat::zeros(xs.rows(), xs.cols());
        }
        // Snapshot the thread-local saturation latch around the narrowing
        // cast and the step so rail clamps (including NaN inputs
        // quantizing to zero) attribute to this engine. Chunks step
        // serially per shard thread, so nothing else writes the latch
        // between the clear and the read; for float `T` no event is ever
        // recorded and this is two thread-local accesses per chunk.
        let _ = crate::qfx::take_saturation_events();
        xs.cast_into(&mut self.xs_t);
        self.opt.step_batch(&self.xs_t);
        self.sat_events += crate::qfx::take_saturation_events();
        Ok(())
    }

    fn b(&self) -> Mat64 {
        self.opt.b().cast()
    }

    fn samples_done(&self) -> u64 {
        self.opt.samples_seen()
    }

    fn describe(&self) -> String {
        format!("native-{}/{}", T::type_name(), self.opt.name())
    }

    fn reset_b(&mut self, b: Mat64) {
        assert_eq!(b.shape(), self.opt.b().shape());
        self.opt.b_mut().copy_from(&b.cast());
    }

    fn set_mu(&mut self, mu: f64) {
        // μ lives in f64 hyperparameter space for every precision; the
        // optimizer narrows it per step/batch.
        self.opt.set_mu(mu);
    }

    fn saturation_events(&self) -> u64 {
        self.sat_events
    }

    fn cohort_lane(&self) -> Option<CohortLane> {
        // Fixed-point tenants stay on the per-session path: the cohort
        // pool keys SoA blocks by float precision, and batching Q-format
        // lanes would decouple the saturation latch from its engine.
        let precision = match T::type_name() {
            "f32" => Precision::F32,
            "f64" => Precision::F64,
            _ => return None,
        };
        cohort_lane_for(self.opt.as_ref(), precision)
    }

    fn cohort_sync(&mut self, b: &Mat64, rows: u64) {
        // `b` is the widened image of the lane's `T` state (the cohort
        // lane ran in `T`), so narrowing back is lossless.
        self.opt.b_mut().copy_from(&b.cast());
        self.opt.note_cohort_rows(rows);
    }

    fn cohort_hhat_prev(&self) -> Mat64 {
        self.opt.cohort_hhat_prev()
    }

    fn cohort_sync_smbgd(&mut self, b: &Mat64, hhat_prev: &Mat64, rows: u64) {
        self.opt.cohort_sync_smbgd(b, hhat_prev, rows);
    }

    fn save_state(&self, w: &mut crate::snapshot::SnapWriter) -> Result<()> {
        // The optimizer widens its T state to f64 bits; T → f64 → T is
        // exact, so an f32 tenant round-trips bit-identically too.
        self.opt.save_state(w)
    }

    fn load_state(&mut self, r: &mut crate::snapshot::SnapReader<'_>) -> Result<()> {
        self.opt.load_state(r)
    }
}

/// PJRT engine: executes AOT chunk programs. Holds (B, Ĥ) as Rust state
/// and threads it through successive chunk executions.
pub struct PjrtEngine {
    rt: PjrtRuntime,
    program: String,
    kind: ProgramKind,
    chunk: usize,
    b: Mat64,
    hhat: Mat64,
    mu: f64,
    gamma: f64,
    beta: f64,
    samples: u64,
}

impl PjrtEngine {
    /// Build from an experiment config, selecting the artifact program that
    /// matches (kind, m, n) — and (P, K) for SMBGD.
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Self> {
        let mut rt = PjrtRuntime::new(&cfg.artifacts_dir)
            .with_context(|| format!("opening artifacts dir '{}'", cfg.artifacts_dir))?;
        let (kind, meta) = match cfg.optimizer.kind {
            OptimizerKind::Sgd => {
                let meta = rt
                    .manifest()
                    .find(ProgramKind::Sgd, cfg.m, cfg.n)
                    .with_context(|| {
                        format!("no sgd artifact for m={} n={}", cfg.m, cfg.n)
                    })?
                    .clone();
                (ProgramKind::Sgd, meta)
            }
            OptimizerKind::Smbgd => {
                // Exact P preserves the algorithm's semantics; among those
                // prefer the largest K (amortizes per-call PJRT dispatch —
                // EXPERIMENTS.md §Perf iteration 2). Fall back to any
                // smbgd program with the right dims.
                let meta = rt
                    .manifest()
                    .find_smbgd_largest_k(cfg.m, cfg.n, cfg.optimizer.p)
                    .or_else(|| rt.manifest().find(ProgramKind::Smbgd, cfg.m, cfg.n))
                    .with_context(|| {
                        format!("no smbgd artifact for m={} n={}", cfg.m, cfg.n)
                    })?
                    .clone();
                (ProgramKind::Smbgd, meta)
            }
            OptimizerKind::Mbgd => {
                bail!("MBGD has no AOT artifact (native engine only)")
            }
        };
        let chunk = meta.chunk_samples();
        let name = meta.name.clone();
        // Eagerly compile so the first submit is execute-only.
        rt.warm_all().ok();
        Ok(Self {
            rt,
            program: name,
            kind,
            chunk,
            b: ica::init_b(cfg.n, cfg.m),
            hhat: Mat64::zeros(cfg.n, cfg.n),
            mu: cfg.optimizer.mu,
            gamma: cfg.optimizer.gamma,
            beta: cfg.optimizer.beta,
            samples: 0,
        })
    }

    /// Install an explicit initial separation matrix.
    pub fn set_b(&mut self, b: Mat64) {
        assert_eq!(b.shape(), self.b.shape());
        self.b = b;
    }

    /// The artifact program driving this engine.
    pub fn program_name(&self) -> &str {
        &self.program
    }
}

impl Engine for PjrtEngine {
    fn chunk_size(&self) -> usize {
        self.chunk
    }

    fn submit_chunk(&mut self, xs: &Mat64) -> Result<()> {
        anyhow::ensure!(
            xs.rows() == self.chunk,
            "PJRT engine needs exactly {} samples per chunk, got {}",
            self.chunk,
            xs.rows()
        );
        match self.kind {
            ProgramKind::Sgd => {
                self.b = self.rt.run_sgd_chunk(&self.program, &self.b, xs, self.mu)?;
            }
            ProgramKind::Smbgd => {
                let out = self.rt.run_smbgd_chunk(
                    &self.program,
                    &self.b,
                    &self.hhat,
                    xs,
                    self.gamma,
                    self.beta,
                    self.mu,
                )?;
                self.b = out.b;
                self.hhat = out.hhat;
            }
            _ => bail!("engine program must be sgd or smbgd"),
        }
        self.samples += xs.rows() as u64;
        Ok(())
    }

    fn b(&self) -> Mat64 {
        self.b.clone()
    }

    fn samples_done(&self) -> u64 {
        self.samples
    }

    fn describe(&self) -> String {
        format!("pjrt/{} ({})", self.program, self.rt.platform())
    }

    fn reset_b(&mut self, b: Mat64) {
        assert_eq!(b.shape(), self.b.shape());
        self.b = b;
        // The Eq. 1 accumulator is stale after a reset too.
        self.hhat.fill(0.0);
    }

    fn set_mu(&mut self, mu: f64) {
        assert!(mu > 0.0);
        self.mu = mu;
    }
}

/// Build the engine selected by the config (engine kind × precision).
pub fn make_engine(cfg: &ExperimentConfig, g: Nonlinearity) -> Result<Box<dyn Engine>> {
    Ok(match (cfg.engine, cfg.precision) {
        (EngineKind::Native, Precision::F64) => Box::new(NativeEngine::from_config(cfg, g)),
        (EngineKind::Native, Precision::F32) => {
            Box::new(CastNativeEngine::<f32>::from_config(cfg, g))
        }
        (EngineKind::Native, Precision::Q16) => {
            Box::new(CastNativeEngine::<crate::qfx::Q16>::from_config(cfg, g))
        }
        (EngineKind::Native, Precision::Q32) => {
            Box::new(CastNativeEngine::<crate::qfx::Q32>::from_config(cfg, g))
        }
        (EngineKind::Pjrt, Precision::F64) => Box::new(PjrtEngine::from_config(cfg)?),
        (EngineKind::Pjrt, p) => {
            bail!("precision = \"{}\" requires the native engine", p.name())
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::signal::Pcg32;

    #[test]
    fn native_engine_tracks_optimizer() {
        let cfg = ExperimentConfig::default();
        let mut eng = NativeEngine::from_config(&cfg, Nonlinearity::Cube);
        let mut rng = Pcg32::seed(1);
        let xs = Mat64::from_fn(eng.chunk_size(), cfg.m, |_, _| rng.normal());
        let b0 = eng.b();
        eng.submit_chunk(&xs).unwrap();
        assert_eq!(eng.samples_done(), eng.chunk_size() as u64);
        assert!(eng.b().max_abs_diff(&b0) > 0.0);
        assert!(eng.describe().starts_with("native/"));
    }

    #[test]
    fn native_engine_chunk_flexible() {
        let cfg = ExperimentConfig::default();
        let mut eng = NativeEngine::from_config(&cfg, Nonlinearity::Cube);
        let xs = Mat64::zeros(3, cfg.m); // any chunk size works
        eng.submit_chunk(&xs).unwrap();
        assert_eq!(eng.samples_done(), 3);
    }

    #[test]
    fn f32_engine_tracks_optimizer_and_reports_precision() {
        let mut cfg = ExperimentConfig::default();
        cfg.precision = Precision::F32;
        let mut eng = CastNativeEngine::<f32>::from_config(&cfg, Nonlinearity::Cube);
        let mut rng = Pcg32::seed(2);
        let xs = Mat64::from_fn(eng.chunk_size(), cfg.m, |_, _| rng.normal());
        let b0 = eng.b();
        eng.submit_chunk(&xs).unwrap();
        assert_eq!(eng.samples_done(), eng.chunk_size() as u64);
        assert!(eng.b().max_abs_diff(&b0) > 0.0);
        assert!(eng.describe().starts_with("native-f32/"), "{}", eng.describe());
        // Snapshot is the widened image of the f32 state: round-trips
        // exactly through a narrow-and-widen.
        let b = eng.b();
        assert_eq!(b, b.cast::<f32>().cast::<f64>());
        // reset_b narrows the warm start exactly (0.5 is representable).
        eng.reset_b(crate::ica::init_b(cfg.n, cfg.m));
        assert_eq!(eng.b(), crate::ica::init_b(cfg.n, cfg.m));
    }

    #[test]
    fn make_engine_selects_precision() {
        let mut cfg = ExperimentConfig::default();
        let e64 = make_engine(&cfg, Nonlinearity::Cube).unwrap();
        assert!(e64.describe().starts_with("native/"));
        cfg.precision = Precision::F32;
        let e32 = make_engine(&cfg, Nonlinearity::Cube).unwrap();
        assert!(e32.describe().starts_with("native-f32/"));
        cfg.precision = Precision::Q16;
        let eq16 = make_engine(&cfg, Nonlinearity::Cube).unwrap();
        assert!(eq16.describe().starts_with("native-q16/"), "{}", eq16.describe());
        cfg.precision = Precision::Q32;
        let eq32 = make_engine(&cfg, Nonlinearity::Cube).unwrap();
        assert!(eq32.describe().starts_with("native-q32/"), "{}", eq32.describe());
        cfg.engine = EngineKind::Pjrt;
        cfg.precision = Precision::F32;
        assert!(make_engine(&cfg, Nonlinearity::Cube).is_err(), "pjrt+f32 must be rejected");
        cfg.precision = Precision::Q16;
        assert!(make_engine(&cfg, Nonlinearity::Cube).is_err(), "pjrt+q16 must be rejected");
    }

    #[test]
    fn q16_engine_steps_on_lattice_and_latches_saturation() {
        use crate::qfx::Q16;
        let mut cfg = ExperimentConfig::default();
        cfg.precision = Precision::Q16;
        cfg.optimizer.kind = OptimizerKind::Sgd;
        let mut eng = make_engine(&cfg, Nonlinearity::Cube).unwrap();
        // Bounded inputs in [-1, 1]: comfortably inside the Q2.14 rails
        // (unbounded Gaussian tails would clip past ±2 by design).
        let xs = Mat64::from_fn(eng.chunk_size(), cfg.m, |r, c| {
            ((r * 7 + c * 13) % 21) as f64 / 10.0 - 1.0
        });
        let b0 = eng.b();
        eng.submit_chunk(&xs).unwrap();
        assert!(eng.b().max_abs_diff(&b0) > 0.0, "q16 step must move B");
        // Every reported B entry sits exactly on the Q2.14 lattice.
        for &v in eng.b().as_slice() {
            assert_eq!(v, Q16::from_f64(v).to_f64(), "off-lattice value {v}");
        }
        // In-range inputs through the cube step: no saturation events on
        // the healthy path.
        assert_eq!(eng.saturation_events(), 0);
        // A NaN burst quantizes to zero with latched events — the
        // fixed-point analogue of the non-finite divergence signal.
        let bad = Mat64::from_fn(eng.chunk_size(), cfg.m, |r, c| {
            if (r + c) % 3 == 0 {
                f64::NAN
            } else {
                0.1
            }
        });
        eng.submit_chunk(&bad).unwrap();
        assert!(eng.saturation_events() > 0, "NaN inputs must latch saturation events");
        // Fixed-point values are always finite — the float guard is inert.
        assert!(eng.b().is_finite());
    }

    #[test]
    fn q16_engine_state_round_trips_bit_identically() {
        let mut cfg = ExperimentConfig::default();
        cfg.precision = Precision::Q16;
        cfg.optimizer.kind = OptimizerKind::Sgd;
        let mut eng = make_engine(&cfg, Nonlinearity::Tanh).unwrap();
        let mut rng = Pcg32::seed(11);
        let xs = Mat64::from_fn(eng.chunk_size(), cfg.m, |_, _| rng.normal());
        for _ in 0..4 {
            eng.submit_chunk(&xs).unwrap();
        }
        // Detach: every Q-format value is a dyadic rational exact in the
        // f64 snapshot wire, so restore must be bit-identical.
        let mut w = crate::snapshot::SnapWriter::new();
        eng.save_state(&mut w).unwrap();
        let payload = w.into_payload();
        let mut fresh = make_engine(&cfg, Nonlinearity::Tanh).unwrap();
        let mut r = crate::snapshot::SnapReader::from_payload(&payload);
        fresh.load_state(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(fresh.b(), eng.b());
        assert_eq!(fresh.samples_done(), eng.samples_done());
        // And both continue identically.
        eng.submit_chunk(&xs).unwrap();
        fresh.submit_chunk(&xs).unwrap();
        assert_eq!(fresh.b(), eng.b());
    }

    #[test]
    fn set_mu_governs_update_magnitude() {
        // The adaptive control plane's actuator: same chunk, smaller μ,
        // smaller step — across both native engine flavours.
        let mut cfg = ExperimentConfig::default();
        cfg.optimizer.kind = OptimizerKind::Sgd;
        let mut rng = Pcg32::seed(3);
        let xs = Mat64::from_fn(64, cfg.m, |_, _| rng.normal());
        let b0 = crate::ica::init_b(cfg.n, cfg.m);

        for precision in [Precision::F64, Precision::F32] {
            cfg.precision = precision;
            let mut fast = make_engine(&cfg, Nonlinearity::Cube).unwrap();
            let mut slow = make_engine(&cfg, Nonlinearity::Cube).unwrap();
            slow.set_mu(1e-6);
            fast.submit_chunk(&xs).unwrap();
            slow.submit_chunk(&xs).unwrap();
            let moved_fast = fast.b().max_abs_diff(&b0);
            let moved_slow = slow.b().max_abs_diff(&b0);
            assert!(
                moved_slow < moved_fast / 10.0,
                "{precision:?}: slow {moved_slow} vs fast {moved_fast}"
            );
        }
    }

    #[test]
    fn cohort_lane_offered_by_plain_sgd_and_smbgd_natives() {
        let mut cfg = ExperimentConfig::default();
        cfg.optimizer.kind = OptimizerKind::Sgd;
        let e64 = make_engine(&cfg, Nonlinearity::Tanh).unwrap();
        let lane = e64.cohort_lane().expect("plain SGD f64 native is cohort-capable");
        assert_eq!(lane.g, Nonlinearity::Tanh);
        assert_eq!(lane.precision, Precision::F64);
        assert_eq!(lane.mu, cfg.optimizer.mu);
        assert_eq!(lane.form, CohortLaneForm::Sgd);

        cfg.precision = Precision::F32;
        let e32 = make_engine(&cfg, Nonlinearity::Cube).unwrap();
        assert_eq!(e32.cohort_lane().unwrap().precision, Precision::F32);

        // Fixed-point tenants never join a cohort pool: per-session path.
        cfg.precision = Precision::Q16;
        let eq16 = make_engine(&cfg, Nonlinearity::Cube).unwrap();
        assert!(eq16.cohort_lane().is_none(), "q16 stays per-session");

        // Phase 2: plain SMBGD at a batch boundary offers a lane whose
        // form carries P structurally and (γ, β) as per-lane data.
        cfg.precision = Precision::F64;
        cfg.optimizer.kind = OptimizerKind::Smbgd;
        let mut smbgd = make_engine(&cfg, Nonlinearity::Cube).unwrap();
        let lane = smbgd.cohort_lane().expect("plain SMBGD native is cohort-capable");
        assert_eq!(
            lane.form,
            CohortLaneForm::Smbgd {
                p: cfg.optimizer.p,
                gamma: cfg.optimizer.gamma,
                beta: cfg.optimizer.beta,
            }
        );
        // Mid-batch state (a partial chunk left the stream unaligned)
        // withdraws the offer until the tenant realigns.
        let odd = Mat64::from_fn(1, cfg.m, |_, c| 0.1 + c as f64 * 0.05);
        smbgd.submit_chunk(&odd).unwrap();
        assert!(smbgd.cohort_lane().is_none(), "mid-batch SMBGD stays per-session");

        // Mbgd (the plain-average mini-batch form) has no cohort kernel.
        cfg.optimizer.kind = OptimizerKind::Mbgd;
        let mbgd = make_engine(&cfg, Nonlinearity::Cube).unwrap();
        assert!(mbgd.cohort_lane().is_none(), "mbgd stays per-session");
    }

    #[test]
    fn smbgd_cohort_sync_round_trips_accumulator() {
        let mut cfg = ExperimentConfig::default();
        cfg.optimizer.kind = OptimizerKind::Smbgd;
        for precision in [Precision::F64, Precision::F32] {
            cfg.precision = precision;
            let mut eng = make_engine(&cfg, Nonlinearity::Cube).unwrap();
            assert_eq!(eng.cohort_hhat_prev(), Mat64::zeros(cfg.n, cfg.n));
            let mut b = eng.b();
            b.scale(0.25); // exactly representable in both precisions
            let h = Mat64::from_fn(cfg.n, cfg.n, |i, j| (i as f64 - j as f64) * 0.125);
            let rows = (cfg.optimizer.p * 16) as u64;
            eng.cohort_sync_smbgd(&b, &h, rows);
            assert_eq!(eng.b(), b, "{precision:?}: installed B must round-trip");
            assert_eq!(eng.cohort_hhat_prev(), h, "{precision:?}: Ĥ_prev must round-trip");
            assert_eq!(eng.samples_done(), rows);
        }
    }

    #[test]
    fn cohort_sync_installs_b_and_accounts_rows() {
        let mut cfg = ExperimentConfig::default();
        cfg.optimizer.kind = OptimizerKind::Sgd;
        for precision in [Precision::F64, Precision::F32] {
            cfg.precision = precision;
            let mut eng = make_engine(&cfg, Nonlinearity::Cube).unwrap();
            let mut b = eng.b();
            b.scale(0.25); // exactly representable in both precisions
            eng.cohort_sync(&b, 192);
            assert_eq!(eng.b(), b, "{precision:?}: installed B must round-trip");
            assert_eq!(eng.samples_done(), 192);
            // μ reported by the lane tracks the governor's actuator.
            eng.set_mu(0.5 * cfg.optimizer.mu);
            assert_eq!(eng.cohort_lane().unwrap().mu, 0.5 * cfg.optimizer.mu);
        }
    }

    #[test]
    fn mbgd_has_no_pjrt_engine() {
        let mut cfg = ExperimentConfig::default();
        cfg.optimizer.kind = OptimizerKind::Mbgd;
        cfg.artifacts_dir = crate::runtime::default_artifacts_dir()
            .to_string_lossy()
            .into_owned();
        if !crate::runtime::pjrt_enabled() || !crate::runtime::artifacts_available() {
            return; // needs the `pjrt` feature and `make artifacts`
        }
        assert!(PjrtEngine::from_config(&cfg).is_err());
    }
}
