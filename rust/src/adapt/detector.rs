//! Drift detector: a self-arming Page–Hinkley (CUSUM) test on the
//! residual-whiteness statistic from [`super::MomentTracker`].
//!
//! The detector classifies the stream into three regimes:
//!
//! - **steady state** — armed, no alarm: the statistic fluctuates around
//!   its post-convergence baseline;
//! - **abrupt drift** — the smoothed statistic jumps past an absolute
//!   level (`abrupt_level`) within the tracker's short memory, the
//!   signature of a mixing-matrix switch;
//! - **gradual drift** — the Page–Hinkley cumulative excess over the
//!   running mean crosses `ph_lambda` without the instantaneous level
//!   tripping, the signature of slow rotation.
//!
//! **Arming.** A whiteness residual is only meaningful once the separator
//! has converged — at stream start B is a warm start and the statistic is
//! large for entirely non-drift reasons. The detector therefore stays
//! disarmed until the statistic first falls below `armed_level`, and
//! re-disarms after every alarm until the separator has re-converged. This
//! is what makes the false-positive rate on a stationary stream ~zero
//! (pinned by `tests/integration_adapt.rs`) without any warmup constant.

/// Drift classification reported on an alarm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriftClass {
    /// Step change (mixing-matrix switch): instantaneous level trip.
    Abrupt,
    /// Slow accumulation (rotation/drift): Page–Hinkley trip.
    Gradual,
}

/// One-sided Page–Hinkley test for an increase of the input's mean.
///
/// Textbook form: with running mean `x̄_t` of all inputs since reset,
/// `m_t = Σ_{i≤t} (x_i − x̄_i − δ)` and `M_t = min_{i≤t} m_i`; alarm when
/// `m_t − M_t > λ`. `δ` sets the insensitivity band, `λ` the evidence
/// required.
#[derive(Clone, Copy, Debug)]
pub struct PageHinkley {
    delta: f64,
    lambda: f64,
    count: u64,
    mean: f64,
    m: f64,
    m_min: f64,
}

impl PageHinkley {
    pub fn new(delta: f64, lambda: f64) -> Self {
        assert!(delta >= 0.0, "delta must be non-negative");
        assert!(lambda > 0.0, "lambda must be positive");
        Self { delta, lambda, count: 0, mean: 0.0, m: 0.0, m_min: 0.0 }
    }

    /// Fold one observation; true means the test fired (caller resets).
    pub fn update(&mut self, x: f64) -> bool {
        self.count += 1;
        self.mean += (x - self.mean) / self.count as f64;
        self.m += x - self.mean - self.delta;
        if self.m < self.m_min {
            self.m_min = self.m;
        }
        self.m - self.m_min > self.lambda
    }

    /// Clear all accumulated state (post-alarm, or on re-arming).
    pub fn reset(&mut self) {
        self.count = 0;
        self.mean = 0.0;
        self.m = 0.0;
        self.m_min = 0.0;
    }

    /// Running mean of the inputs since the last reset.
    pub fn mean(&self) -> f64 {
        self.mean
    }
}

/// Detector tuning knobs (a copy of the `adapt.*` config subset it uses).
#[derive(Clone, Copy, Debug)]
pub struct DetectorParams {
    /// Arm (and re-arm) once the statistic falls below this level.
    pub armed_level: f64,
    /// Instantaneous statistic at or above this level → [`DriftClass::Abrupt`].
    pub abrupt_level: f64,
    /// Page–Hinkley insensitivity band δ.
    pub ph_delta: f64,
    /// Page–Hinkley alarm threshold λ.
    pub ph_lambda: f64,
}

impl DetectorParams {
    pub fn validate(&self) {
        assert!(
            self.armed_level > 0.0 && self.armed_level < self.abrupt_level,
            "need 0 < armed_level < abrupt_level, got {} / {}",
            self.armed_level,
            self.abrupt_level
        );
        assert!(self.ph_delta >= 0.0, "ph_delta must be non-negative");
        assert!(self.ph_lambda > 0.0, "ph_lambda must be positive");
    }
}

/// Self-arming drift detector over the whiteness-residual statistic.
pub struct DriftDetector {
    params: DetectorParams,
    ph: PageHinkley,
    armed: bool,
    /// The statistic has been observed at/above `armed_level` at least
    /// once. Arming requires a high→low excursion, not merely a low
    /// value: for large channel counts the *unconverged* residual can
    /// start below `armed_level` (the per-entry RMS scales down with n),
    /// and arming on that would turn the initial convergence transient
    /// into a false abrupt alarm. Requiring the excursion makes such
    /// streams fail safe (never armed → never alarmed) instead. Sticky:
    /// once seen, disarm/re-arm cycles do not require a new excursion.
    seen_high: bool,
    last_stat: f64,
}

impl DriftDetector {
    pub fn new(params: DetectorParams) -> Self {
        params.validate();
        Self {
            ph: PageHinkley::new(params.ph_delta, params.ph_lambda),
            params,
            armed: false,
            seen_high: false,
            last_stat: f64::INFINITY,
        }
    }

    /// True once the statistic has dropped into the steady-state band
    /// (drift can only be declared while armed).
    pub fn armed(&self) -> bool {
        self.armed
    }

    /// Most recent statistic value observed.
    pub fn last_stat(&self) -> f64 {
        self.last_stat
    }

    /// Fold one statistic observation; returns the drift class on alarm.
    /// After an alarm the detector disarms itself and re-arms when the
    /// statistic next falls below `armed_level` (having been above it at
    /// least once over the detector's lifetime — see `seen_high`).
    pub fn update(&mut self, stat: f64) -> Option<DriftClass> {
        self.last_stat = stat;
        if !self.armed {
            if stat >= self.params.armed_level {
                self.seen_high = true;
            } else if self.seen_high {
                self.armed = true;
                self.ph.reset();
            }
            return None;
        }
        if stat >= self.params.abrupt_level {
            self.armed = false;
            self.ph.reset();
            return Some(DriftClass::Abrupt);
        }
        if self.ph.update(stat) {
            self.armed = false;
            self.ph.reset();
            return Some(DriftClass::Gradual);
        }
        None
    }

    /// Force disarm (used after a rollback: the separator state just
    /// changed discontinuously, so the statistic must re-settle before
    /// drift is meaningful again).
    pub fn disarm(&mut self) {
        self.armed = false;
        self.ph.reset();
    }

    /// Serialize the detection state (detach-to-disk; the params and the
    /// δ/λ thresholds inside the Page–Hinkley test are config-derived at
    /// rebuild time).
    pub fn save_state(&self, w: &mut crate::snapshot::SnapWriter) {
        w.put_u64(self.ph.count);
        w.put_f64(self.ph.mean);
        w.put_f64(self.ph.m);
        w.put_f64(self.ph.m_min);
        w.put_bool(self.armed);
        w.put_bool(self.seen_high);
        w.put_f64(self.last_stat);
    }

    /// Rehydrate the state written by [`save_state`](Self::save_state).
    pub fn load_state(&mut self, r: &mut crate::snapshot::SnapReader<'_>) -> anyhow::Result<()> {
        self.ph.count = r.get_u64()?;
        self.ph.mean = r.get_f64()?;
        self.ph.m = r.get_f64()?;
        self.ph.m_min = r.get_f64()?;
        self.armed = r.get_bool()?;
        self.seen_high = r.get_bool()?;
        self.last_stat = r.get_f64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> DetectorParams {
        DetectorParams { armed_level: 0.25, abrupt_level: 0.6, ph_delta: 0.04, ph_lambda: 3.0 }
    }

    #[test]
    fn stays_disarmed_until_convergence() {
        let mut d = DriftDetector::new(params());
        // Pre-convergence: large statistic, no alarms ever.
        for _ in 0..100 {
            assert_eq!(d.update(1.5), None);
        }
        assert!(!d.armed());
        assert_eq!(d.update(0.1), None); // arming itself is not an alarm
        assert!(d.armed());
    }

    #[test]
    fn never_arms_without_a_high_excursion() {
        // Large-n streams whose unconverged residual already sits below
        // armed_level must fail safe: no arming, hence no false alarms —
        // even when the statistic later rises past the abrupt level.
        let mut d = DriftDetector::new(params());
        for _ in 0..100 {
            assert_eq!(d.update(0.1), None);
        }
        assert!(!d.armed(), "a low start must not arm");
        assert_eq!(d.update(0.9), None, "unarmed detector never alarms");
    }

    #[test]
    fn abrupt_jump_classified_abrupt() {
        let mut d = DriftDetector::new(params());
        d.update(1.0); // unconverged start (the high excursion)
        d.update(0.1); // convergence → arms
        for _ in 0..200 {
            assert_eq!(d.update(0.12), None);
        }
        assert_eq!(d.update(0.9), Some(DriftClass::Abrupt));
        // Disarmed while re-converging: the still-high statistic must not
        // re-alarm.
        assert_eq!(d.update(0.9), None);
        assert!(!d.armed());
        // Re-arms after recovery, and can fire again.
        d.update(0.1);
        assert!(d.armed());
        assert_eq!(d.update(0.9), Some(DriftClass::Abrupt));
    }

    #[test]
    fn slow_ramp_classified_gradual() {
        let mut d = DriftDetector::new(params());
        d.update(1.0);
        d.update(0.1);
        for _ in 0..100 {
            assert_eq!(d.update(0.1), None);
        }
        // Sustained shift to 0.35: below the abrupt level, but PH
        // accumulates (0.35 − mean − δ) per step and must fire.
        let mut fired = None;
        for k in 0..400 {
            if let Some(c) = d.update(0.35) {
                fired = Some((k, c));
                break;
            }
        }
        let (k, class) = fired.expect("gradual drift must alarm");
        assert_eq!(class, DriftClass::Gradual);
        assert!(k < 200, "PH took {k} steps");
    }

    #[test]
    fn stationary_noise_never_alarms() {
        let mut d = DriftDetector::new(params());
        d.update(1.0); // unconverged start, then settle
        let mut rng = crate::signal::Pcg32::seed(0xD1F7);
        // 50k observations of noise around 0.12 (the steady-state regime).
        for _ in 0..50_000 {
            let stat = (0.12 + 0.04 * rng.normal()).abs();
            assert_eq!(d.update(stat), None, "false alarm on stationary noise");
        }
        assert!(d.armed());
    }

    #[test]
    fn page_hinkley_mean_tracks() {
        let mut ph = PageHinkley::new(0.0, 1e9);
        for x in [1.0, 2.0, 3.0] {
            ph.update(x);
        }
        assert!((ph.mean() - 2.0).abs() < 1e-12);
        ph.reset();
        assert_eq!(ph.mean(), 0.0);
    }

    #[test]
    fn disarm_suppresses_and_rearms() {
        let mut d = DriftDetector::new(params());
        d.update(1.0);
        d.update(0.1);
        assert!(d.armed());
        d.disarm();
        assert_eq!(d.update(0.9), None, "disarmed detector must not alarm");
        // seen_high is sticky: re-arming needs no fresh excursion.
        d.update(0.1);
        assert!(d.armed());
    }

    #[test]
    #[should_panic(expected = "armed_level")]
    fn bad_params_rejected() {
        DriftDetector::new(DetectorParams {
            armed_level: 0.7,
            abrupt_level: 0.6,
            ph_delta: 0.04,
            ph_lambda: 3.0,
        });
    }
}
