//! Learning-rate governor: the closed-loop μ(t) law behind
//! [`crate::ica::MuSchedule::Adaptive`].
//!
//! The control law combines three regimes:
//!
//! - **anneal** — between drift events μ decays as `μ₀ / (1 + t'/τ)`
//!   (Robbins–Monro shape, matching `MuSchedule::DecayToFloor`), where
//!   `t'` restarts at the last boost;
//! - **boost** — on a detected drift event μ jumps to `boost·μ₀` and the
//!   anneal clock restarts, buying back tracking speed exactly when the
//!   mixing moved;
//! - **moment floor** — the anneal never goes below
//!   `clamp(floor_c / m̂₄, floor_min, μ₀)`, where `m̂₄` is the tracked
//!   normalized fourth moment of the outputs. Per Gültekin et al.
//!   ("Learning Rate Should Scale Inversely with High-Order Data Moments
//!   in High-Dimensional Online ICA"), the steady-state-optimal rate
//!   scales inversely with the data's high-order moments: heavy-tailed
//!   outputs (large m̂₄) push the floor down for stability, maximally
//!   sub-Gaussian outputs (small m̂₄) let it ride higher for tracking.
//!
//! After a rollback (a post-drift step diverged and the coordinator
//! restored the checkpoint) the boost is cancelled — μ returns to the
//! from-start anneal, i.e. near the floor — so the recovered state is not
//! immediately re-destabilized.

/// Hard ceiling on any governed μ; config-level validation requires
/// μ ∈ (0, 1) and boosted rates must stay well inside the stable region.
pub const MU_MAX: f64 = 0.2;

/// Governor tuning knobs (a copy of the `adapt.*` config subset it uses).
#[derive(Clone, Copy, Debug)]
pub struct GovernorParams {
    /// Base learning rate μ₀ (the session's configured optimizer μ).
    pub mu0: f64,
    /// Multiplier applied to μ₀ on a detected drift event (≥ 1).
    pub boost: f64,
    /// Anneal time constant τ, in samples.
    pub tau: f64,
    /// Inverse-moment floor constant: floor = `floor_c / m̂₄` (clamped).
    pub floor_c: f64,
    /// Lower clamp of the floor.
    pub floor_min: f64,
}

impl GovernorParams {
    pub fn validate(&self) {
        assert!(self.mu0 > 0.0 && self.mu0 < 1.0, "mu0 in (0,1), got {}", self.mu0);
        assert!(self.boost >= 1.0, "boost must be >= 1, got {}", self.boost);
        assert!(self.tau > 0.0, "tau must be positive");
        assert!(self.floor_c >= 0.0, "floor_c must be non-negative");
        assert!(
            self.floor_min > 0.0 && self.floor_min <= MU_MAX,
            "floor_min in (0, {MU_MAX}], got {}",
            self.floor_min
        );
    }
}

/// The stateful μ(t) controller.
#[derive(Clone, Copy, Debug)]
pub struct Governor {
    params: GovernorParams,
    /// Sample index of the last boost (anneal clock restart), if any.
    boosted_at: Option<u64>,
    boosts: u64,
}

impl Governor {
    pub fn new(params: GovernorParams) -> Self {
        params.validate();
        Self { params, boosted_at: None, boosts: 0 }
    }

    pub fn params(&self) -> GovernorParams {
        self.params
    }

    /// The moment-scaled floor for a tracked normalized fourth moment.
    /// The floor can never exceed μ₀ — a base rate below `floor_min`
    /// (micro-μ bench configs) caps the floor at μ₀ itself.
    pub fn floor(&self, m4_norm: f64) -> f64 {
        let p = &self.params;
        let hi = p.mu0.min(MU_MAX);
        let lo = p.floor_min.min(hi);
        (p.floor_c / m4_norm.max(1e-6)).clamp(lo, hi)
    }

    /// μ at sample `t` given the tracked normalized fourth moment.
    pub fn mu(&self, t: u64, m4_norm: f64) -> f64 {
        let p = &self.params;
        let (base, elapsed) = match self.boosted_at {
            Some(t0) => ((p.boost * p.mu0).min(MU_MAX), t.saturating_sub(t0)),
            None => (p.mu0.min(MU_MAX), t),
        };
        (base / (1.0 + elapsed as f64 / p.tau)).max(self.floor(m4_norm))
    }

    /// A drift event was detected at sample `t`: boost and restart the
    /// anneal clock.
    pub fn on_drift(&mut self, t: u64) {
        self.boosted_at = Some(t);
        self.boosts += 1;
    }

    /// A post-drift step diverged and was rolled back: cancel the boost so
    /// μ returns to the from-start anneal (≈ the floor).
    pub fn on_rollback(&mut self) {
        self.boosted_at = None;
    }

    /// Drift boosts applied over the governor's lifetime.
    pub fn boosts(&self) -> u64 {
        self.boosts
    }

    /// Sample index of the last boost, if one is active.
    pub fn boosted_at(&self) -> Option<u64> {
        self.boosted_at
    }

    /// Serialize the control state (detach-to-disk; the params are
    /// config-derived at rebuild time).
    pub fn save_state(&self, w: &mut crate::snapshot::SnapWriter) {
        w.put_opt_u64(self.boosted_at);
        w.put_u64(self.boosts);
    }

    /// Rehydrate the state written by [`save_state`](Self::save_state).
    pub fn load_state(&mut self, r: &mut crate::snapshot::SnapReader<'_>) -> anyhow::Result<()> {
        self.boosted_at = r.get_opt_u64()?;
        self.boosts = r.get_u64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> GovernorParams {
        GovernorParams { mu0: 0.01, boost: 2.0, tau: 4000.0, floor_c: 0.003, floor_min: 2e-4 }
    }

    #[test]
    fn anneals_from_mu0_to_floor() {
        let g = Governor::new(params());
        let m4 = 1.8; // sub-Gaussian bank
        assert!((g.mu(0, m4) - 0.01).abs() < 1e-12);
        assert!(g.mu(4000, m4) < 0.0051);
        // Deep anneal pins at the moment floor.
        let floor = g.floor(m4);
        assert!((g.mu(10_000_000, m4) - floor).abs() < 1e-12);
        assert!((floor - 0.003 / 1.8).abs() < 1e-12);
    }

    #[test]
    fn boost_raises_then_reanneals() {
        let mut g = Governor::new(params());
        let m4 = 1.8;
        let settled = g.mu(100_000, m4);
        g.on_drift(100_000);
        let boosted = g.mu(100_000, m4);
        assert!((boosted - 0.02).abs() < 1e-12, "boosted mu {boosted}");
        assert!(boosted > 5.0 * settled);
        // Anneals back down after the event.
        assert!(g.mu(104_000, m4) < 0.6 * boosted);
        assert_eq!(g.boosts(), 1);
        assert_eq!(g.boosted_at(), Some(100_000));
    }

    #[test]
    fn floor_scales_inversely_with_fourth_moment() {
        let g = Governor::new(params());
        // Heavy-tailed outputs → lower floor; sub-Gaussian → higher.
        assert!(g.floor(8.0) < g.floor(1.5));
        assert!((g.floor(3.0) - 0.001).abs() < 1e-12);
        // Clamps hold at both ends.
        assert_eq!(g.floor(1e9), params().floor_min);
        assert_eq!(g.floor(1e-9), params().mu0);
    }

    #[test]
    fn rollback_cancels_boost() {
        let mut g = Governor::new(params());
        let m4 = 2.0;
        g.on_drift(50_000);
        assert!(g.mu(50_000, m4) > 0.015);
        g.on_rollback();
        // Back on the from-start anneal: deep in the floor regime.
        assert!((g.mu(50_000, m4) - g.floor(m4)).abs() < 1e-9);
        assert_eq!(g.boosted_at(), None);
    }

    #[test]
    fn mu_respects_ceiling() {
        let mut g = Governor::new(GovernorParams {
            mu0: 0.15,
            boost: 10.0,
            tau: 1000.0,
            floor_c: 0.003,
            floor_min: 2e-4,
        });
        g.on_drift(0);
        assert!(g.mu(0, 2.0) <= MU_MAX);
    }

    #[test]
    #[should_panic(expected = "boost")]
    fn bad_boost_rejected() {
        Governor::new(GovernorParams { boost: 0.5, ..params() });
    }
}
