//! Adaptive control plane: the per-session closed loop that watches the
//! separated outputs and governs the learning rate.
//!
//! The paper's value proposition over nonadaptive ICA is that EASI
//! *tracks* changes in the underlying distributions (§I, §III) — but
//! tracking speed and steady-state error pull against each other through
//! one knob, μ. This subsystem closes the loop on that knob per session:
//!
//! ```text
//!             ┌──────────────────────────────────────────────┐
//!             │                SessionRunner                 │
//!   x ──AGC──►│ Engine (B ← B − μHB) ──► y = Bx (strided)    │
//!             │        ▲                    │                │
//!             │        │ set_mu          MomentTracker       │
//!             │   Governor ◄── DriftDetector ◄── whiteness   │
//!             │        │             │                       │
//!             │        └─ boost ◄────┴─► Monitor::rearm      │
//!             │                          checkpoint/rollback │
//!             └──────────────────────────────────────────────┘
//! ```
//!
//! - [`MomentTracker`] — EW per-channel variance/fourth moment and the
//!   `EW[y yᵀ]` matrix (the same `y·yᵀ` terms the EASI gradient builds),
//!   zero-alloc, [`crate::linalg::Scalar`]-generic.
//! - [`DriftDetector`] — self-arming Page–Hinkley/CUSUM on the
//!   residual-whiteness statistic `‖EW[y yᵀ] − I‖_F / n`, classifying
//!   steady state vs abrupt vs gradual drift.
//! - [`Governor`] — the [`crate::ica::MuSchedule::Adaptive`] law: boost μ
//!   on drift, anneal toward a floor scaled inversely with the tracked
//!   fourth moment (Gültekin et al.), cool after a rollback.
//! - [`AdaptiveController`] — composes the three per session, owns the
//!   recovery checkpoint of B, and is what `coordinator::SessionRunner`
//!   drives per chunk (config `adapt.enabled`, CLI `--adapt`).
//! - [`AdaptiveSgd`] — an [`Optimizer`] wrapper running the same loop
//!   per sample, used by the offline drift study
//!   (`experiments::drift_study`, CLI `track`).

pub mod detector;
pub mod governor;
pub mod moments;

pub use detector::{DetectorParams, DriftClass, DriftDetector, PageHinkley};
pub use governor::{Governor, GovernorParams, MU_MAX};
pub use moments::MomentTracker;

use crate::config::AdaptConfig;
use crate::ica::{EasiSgd, Nonlinearity, Optimizer};
use crate::linalg::{Mat, Mat64};

/// Per-session closed-loop controller: moment tracker + drift detector +
/// learning-rate governor + recovery checkpoint.
///
/// The controller observes in `f64` (the coordinator's wire format — the
/// engine's `B` snapshots are widened there regardless of the session's
/// request-path precision) and decimates observations by `stride` to keep
/// the hot-path overhead bounded (the `adapt_overhead_fraction` record in
/// the §Perf suite, gated < 10% in CI).
pub struct AdaptiveController {
    tracker: MomentTracker<f64>,
    detector: DriftDetector,
    governor: Governor,
    stride: usize,
    /// Rows offered since the last observation (stride phase).
    phase: usize,
    /// Scratch for `y = B x` (length n) — reused, zero-alloc.
    y: Vec<f64>,
    /// Last known-good separation matrix (steady-state snapshots).
    checkpoint: Mat64,
    checkpoint_valid: bool,
    rollback_enabled: bool,
    drift_events: u64,
    abrupt_events: u64,
    rollbacks: u64,
    last_drift_at: Option<u64>,
}

impl AdaptiveController {
    /// Build for an `n × m` separation matrix with base learning rate
    /// `mu0` (the session's configured optimizer μ).
    pub fn new(cfg: &AdaptConfig, mu0: f64, n: usize, m: usize) -> Self {
        cfg.validate().expect("adapt config validated upstream");
        Self {
            tracker: MomentTracker::new(n, cfg.alpha),
            detector: DriftDetector::new(DetectorParams {
                armed_level: cfg.armed_level,
                abrupt_level: cfg.abrupt_level,
                ph_delta: cfg.ph_delta,
                ph_lambda: cfg.ph_lambda,
            }),
            governor: Governor::new(GovernorParams {
                mu0,
                boost: cfg.boost,
                tau: cfg.tau,
                floor_c: cfg.floor_c,
                floor_min: cfg.floor_min,
            }),
            stride: cfg.stride.max(1),
            phase: 0,
            y: vec![0.0; n],
            checkpoint: Mat64::zeros(n, m),
            checkpoint_valid: false,
            rollback_enabled: cfg.rollback,
            drift_events: 0,
            abrupt_events: 0,
            rollbacks: 0,
            last_drift_at: None,
        }
    }

    /// Fold one already-separated output sample `y` (no stride — the
    /// caller decides what to observe). `t` is the global sample index.
    pub fn observe_y(&mut self, y: &[f64], t: u64) -> Option<DriftClass> {
        self.tracker.update(y);
        let stat = self.tracker.whiteness_residual();
        let event = self.detector.update(stat);
        if let Some(class) = event {
            self.governor.on_drift(t);
            self.drift_events += 1;
            if class == DriftClass::Abrupt {
                self.abrupt_events += 1;
            }
            self.last_drift_at = Some(t);
            // The checkpoint pre-dates the drift: keep it — it is exactly
            // the state to restore if the boosted re-tracking diverges.
        }
        event
    }

    /// Offer one input sample; observed only on stride hits, computing
    /// `y = B x` into the reusable scratch. `t` is the global sample index.
    pub fn observe_x(&mut self, b: &Mat64, x: &[f64], t: u64) -> Option<DriftClass> {
        self.phase += 1;
        if self.phase < self.stride {
            return None;
        }
        self.phase = 0;
        let mut y = std::mem::take(&mut self.y);
        b.matvec_into(x, &mut y);
        let event = self.observe_y(&y, t);
        self.y = y;
        event
    }

    /// Offer a whole ingested chunk (rows ending at global sample index
    /// `end_t`), observing stride hits against the post-update `b`.
    /// Returns the most severe event seen in the chunk (abrupt > gradual).
    pub fn observe_chunk(&mut self, b: &Mat64, chunk: &Mat64, end_t: u64) -> Option<DriftClass> {
        let rows = chunk.rows() as u64;
        let first = end_t.saturating_sub(rows.saturating_sub(1));
        let mut worst = None;
        for r in 0..chunk.rows() {
            if let Some(class) = self.observe_x(b, chunk.row(r), first + r as u64) {
                worst = Some(match (worst, class) {
                    (Some(DriftClass::Abrupt), _) | (_, DriftClass::Abrupt) => DriftClass::Abrupt,
                    _ => DriftClass::Gradual,
                });
            }
        }
        worst
    }

    /// The governed learning rate at global sample index `t`.
    pub fn mu(&self, t: u64) -> f64 {
        self.governor.mu(t, self.tracker.normalized_fourth_moment())
    }

    /// Record `b` as the recovery checkpoint if the stream currently looks
    /// steady (detector armed, no alarm pending). Cheap: one `copy_from`
    /// of the tiny n × m matrix, no allocation.
    pub fn checkpoint_if_steady(&mut self, b: &Mat64) {
        if self.detector.armed() {
            self.checkpoint.copy_from(b);
            self.checkpoint_valid = true;
        }
    }

    /// The rollback target, if a steady-state checkpoint exists and
    /// rollback is enabled.
    pub fn rollback_b(&self) -> Option<&Mat64> {
        (self.rollback_enabled && self.checkpoint_valid).then_some(&self.checkpoint)
    }

    /// A divergence was recovered (checkpoint or warm start): cool the
    /// governor (cancel any boost) and disarm the detector until the
    /// restored state re-settles — a boosted μ re-applied to a freshly
    /// reset separator would just blow it up again, and the reset itself
    /// spikes the whiteness statistic in a way that is not drift.
    pub fn on_divergence_reset(&mut self) {
        self.governor.on_rollback();
        self.detector.disarm();
    }

    /// A rollback to the steady-state checkpoint was performed: count it
    /// and cool exactly like any divergence recovery.
    pub fn on_rollback(&mut self) {
        self.rollbacks += 1;
        self.on_divergence_reset();
    }

    /// Drift events detected over the session (abrupt + gradual).
    pub fn drift_events(&self) -> u64 {
        self.drift_events
    }

    /// Abrupt subset of [`Self::drift_events`].
    pub fn abrupt_events(&self) -> u64 {
        self.abrupt_events
    }

    /// Rollbacks performed over the session.
    pub fn rollbacks(&self) -> u64 {
        self.rollbacks
    }

    /// Global sample index of the most recent drift detection.
    pub fn last_drift_at(&self) -> Option<u64> {
        self.last_drift_at
    }

    /// The moment tracker (read access for reports/tests).
    pub fn tracker(&self) -> &MomentTracker<f64> {
        &self.tracker
    }

    /// Whether the detector is currently armed (steady state reached).
    pub fn armed(&self) -> bool {
        self.detector.armed()
    }

    /// Most recent whiteness-residual statistic.
    pub fn last_stat(&self) -> f64 {
        self.detector.last_stat()
    }

    /// Serialize the full closed-loop state (detach-to-disk): tracker,
    /// detector, governor, stride phase, recovery checkpoint, and event
    /// counters. The `y` scratch is transient and is not persisted; the
    /// config-derived knobs (stride, alpha, thresholds, governor params)
    /// come back from the session config at rebuild time.
    pub fn save_state(&self, w: &mut crate::snapshot::SnapWriter) {
        self.tracker.save_state(w);
        self.detector.save_state(w);
        self.governor.save_state(w);
        w.put_usize(self.phase);
        w.put_mat64(&self.checkpoint);
        w.put_bool(self.checkpoint_valid);
        w.put_u64(self.drift_events);
        w.put_u64(self.abrupt_events);
        w.put_u64(self.rollbacks);
        w.put_opt_u64(self.last_drift_at);
    }

    /// Rehydrate the state written by [`save_state`](Self::save_state).
    pub fn load_state(&mut self, r: &mut crate::snapshot::SnapReader<'_>) -> anyhow::Result<()> {
        self.tracker.load_state(r)?;
        self.detector.load_state(r)?;
        self.governor.load_state(r)?;
        self.phase = r.get_usize()?;
        anyhow::ensure!(
            self.phase < self.stride,
            "snapshot stride phase {} is outside stride {}",
            self.phase,
            self.stride
        );
        let checkpoint: Mat64 = r.get_mat64()?;
        anyhow::ensure!(
            checkpoint.shape() == self.checkpoint.shape(),
            "snapshot checkpoint is {:?}, session expects {:?}",
            checkpoint.shape(),
            self.checkpoint.shape()
        );
        self.checkpoint = checkpoint;
        self.checkpoint_valid = r.get_bool()?;
        self.drift_events = r.get_u64()?;
        self.abrupt_events = r.get_u64()?;
        self.rollbacks = r.get_u64()?;
        self.last_drift_at = r.get_opt_u64()?;
        Ok(())
    }
}

/// Per-sample EASI SGD under the closed-loop governor — the
/// `MuSchedule::Adaptive` counterpart of [`crate::ica::ScheduledSgd`],
/// used by the offline drift study (`experiments::drift_study`) and the
/// `track` CLI command. The streaming path does not use this wrapper: the
/// coordinator drives an [`AdaptiveController`] at chunk granularity
/// against any engine instead.
pub struct AdaptiveSgd {
    inner: EasiSgd<f64>,
    ctrl: AdaptiveController,
    /// Every drift alarm as (sample index, class) — for experiment
    /// reports; the streaming path reads counters off the controller
    /// instead.
    events: Vec<(u64, DriftClass)>,
}

impl AdaptiveSgd {
    pub fn new(n: usize, m: usize, mu0: f64, g: Nonlinearity, cfg: &AdaptConfig) -> Self {
        Self {
            inner: EasiSgd::with_identity_init(n, m, mu0, g),
            ctrl: AdaptiveController::new(cfg, mu0, n, m),
            events: Vec::new(),
        }
    }

    pub fn controller(&self) -> &AdaptiveController {
        &self.ctrl
    }

    pub fn current_mu(&self) -> f64 {
        self.ctrl.mu(self.inner.samples_seen())
    }

    /// Drift alarms fired so far, in order.
    pub fn events(&self) -> &[(u64, DriftClass)] {
        &self.events
    }
}

impl Optimizer for AdaptiveSgd {
    fn step(&mut self, x: &[f64]) {
        let t = self.inner.samples_seen();
        let mu = self.ctrl.mu(t);
        self.inner.set_mu(mu);
        self.inner.step(x);
        if let Some(class) = self.ctrl.observe_x(self.inner.b(), x, t + 1) {
            self.events.push((t + 1, class));
        }
    }

    fn b(&self) -> &Mat<f64> {
        self.inner.b()
    }

    fn b_mut(&mut self) -> &mut Mat<f64> {
        self.inner.b_mut()
    }

    fn samples_seen(&self) -> u64 {
        self.inner.samples_seen()
    }

    fn name(&self) -> &'static str {
        "easi-sgd-adaptive"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::signal::Pcg32;

    fn cfg() -> AdaptConfig {
        AdaptConfig { enabled: true, ..AdaptConfig::default() }
    }

    #[test]
    fn stride_decimates_observations() {
        let mut ctrl = AdaptiveController::new(&cfg(), 0.01, 2, 4);
        let b = crate::ica::init_b(2, 4);
        let x = [0.1, -0.2, 0.3, 0.0];
        for t in 0..100u64 {
            ctrl.observe_x(&b, &x, t);
        }
        assert_eq!(ctrl.tracker().observed(), 100 / cfg().stride as u64);
    }

    #[test]
    fn observe_chunk_matches_per_sample() {
        let mut rng = Pcg32::seed(3);
        let b = crate::ica::init_b(2, 4);
        let chunk = Mat64::from_fn(64, 4, |_, _| rng.normal());
        let mut a = AdaptiveController::new(&cfg(), 0.01, 2, 4);
        let mut s = AdaptiveController::new(&cfg(), 0.01, 2, 4);
        a.observe_chunk(&b, &chunk, 64);
        for r in 0..chunk.rows() {
            s.observe_x(&b, chunk.row(r), 1 + r as u64);
        }
        assert_eq!(a.tracker().observed(), s.tracker().observed());
        assert_eq!(a.last_stat(), s.last_stat());
    }

    #[test]
    fn checkpoint_only_when_armed() {
        let mut ctrl = AdaptiveController::new(&cfg(), 0.01, 2, 2);
        let b = Mat64::eye(2, 2);
        ctrl.checkpoint_if_steady(&b);
        assert!(ctrl.rollback_b().is_none(), "no checkpoint before arming");
        // A white stream arms the detector (stat ~ 0 < armed_level)…
        let s = 2f64.sqrt();
        for t in 0..256u64 {
            let y = if t % 2 == 0 { [s, 0.0] } else { [0.0, s] };
            ctrl.observe_y(&y, t);
        }
        assert!(ctrl.armed());
        ctrl.checkpoint_if_steady(&b);
        let ck = ctrl.rollback_b().expect("checkpoint after arming");
        assert_eq!(ck, &b);
    }

    #[test]
    fn rollback_cools_and_disarms() {
        let mut ctrl = AdaptiveController::new(&cfg(), 0.01, 2, 2);
        let s = 2f64.sqrt();
        for t in 0..256u64 {
            let y = if t % 2 == 0 { [s, 0.0] } else { [0.0, s] };
            ctrl.observe_y(&y, t);
        }
        ctrl.checkpoint_if_steady(&Mat64::eye(2, 2));
        // Abrupt drift: correlated large outputs.
        let mut drifted = false;
        for t in 256..512u64 {
            if ctrl.observe_y(&[2.0, 2.0], t).is_some() {
                drifted = true;
                break;
            }
        }
        assert!(drifted, "correlated outputs must trip the detector");
        assert_eq!(ctrl.drift_events(), 1);
        assert_eq!(ctrl.abrupt_events(), 1);
        assert!(ctrl.last_drift_at().is_some());
        let boosted = ctrl.mu(ctrl.last_drift_at().unwrap());
        ctrl.on_rollback();
        assert_eq!(ctrl.rollbacks(), 1);
        assert!(!ctrl.armed());
        assert!(ctrl.mu(ctrl.last_drift_at().unwrap()) < boosted);
    }

    #[test]
    fn rollback_disabled_yields_no_target() {
        let mut c = cfg();
        c.rollback = false;
        let mut ctrl = AdaptiveController::new(&c, 0.01, 2, 2);
        let s = 2f64.sqrt();
        for t in 0..256u64 {
            let y = if t % 2 == 0 { [s, 0.0] } else { [0.0, s] };
            ctrl.observe_y(&y, t);
        }
        ctrl.checkpoint_if_steady(&Mat64::eye(2, 2));
        assert!(ctrl.rollback_b().is_none());
    }

    #[test]
    fn adaptive_sgd_steps_and_reports() {
        let mut opt = AdaptiveSgd::new(2, 4, 0.01, Nonlinearity::Cube, &cfg());
        let mut rng = Pcg32::seed(5);
        for _ in 0..500 {
            let x = [rng.normal(), rng.normal(), rng.normal(), rng.normal()];
            opt.step(&x);
        }
        assert_eq!(opt.samples_seen(), 500);
        assert_eq!(opt.name(), "easi-sgd-adaptive");
        assert!(opt.b().is_finite());
        assert!(opt.current_mu() > 0.0 && opt.current_mu() < MU_MAX + 1e-12);
        assert_eq!(
            opt.controller().tracker().observed(),
            500 / cfg().stride as u64
        );
    }
}
