//! Online moment tracker: exponentially-weighted estimates of the
//! separated outputs' second- and fourth-order statistics.
//!
//! Two results motivate tracking these online. Wang & Lu ("The Scaling
//! Limit of High-Dimensional Online ICA") show the steady-state error and
//! tracking speed of online ICA are governed by the learning rate relative
//! to the data's moments; Gültekin et al. ("Learning Rate Should Scale
//! Inversely with High-Order Data Moments in High-Dimensional Online ICA")
//! sharpen that to an inverse fourth-moment scaling law. The
//! [`super::Governor`] closes the loop on exactly that quantity, and the
//! [`super::DriftDetector`] reads the tracked `E[y yᵀ]` as its
//! residual-whiteness statistic — the same `y·yᵀ` terms the EASI gradient
//! already builds (`H = y yᵀ − I + …`), re-estimated here as slow EW
//! averages instead of per-sample outer products.
//!
//! Zero allocations after construction (asserted by the counting-allocator
//! test in `rust/tests/fused_hotpath.rs`), and generic over the request
//! path's [`Scalar`] precision like the PR-3 kernels.

use crate::linalg::{Mat, Scalar};

/// EW estimator of per-channel variance/fourth moment and the full
/// second-moment matrix `Ĉ = EW[y yᵀ]` of the separated outputs.
pub struct MomentTracker<T: Scalar = f64> {
    alpha: T,
    /// Per-channel EW `E[y_i²]`.
    m2: Vec<T>,
    /// Per-channel EW `E[y_i⁴]`.
    m4: Vec<T>,
    /// EW `E[y yᵀ]` (n × n, symmetric by construction).
    cross: Mat<T>,
    observed: u64,
}

impl<T: Scalar> MomentTracker<T> {
    /// Tracker for `n` output channels with EW coefficient `alpha`
    /// (per observation; memory ≈ 1/alpha observations).
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n >= 1, "tracker needs at least one channel");
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha in (0, 1], got {alpha}");
        Self {
            alpha: T::scalar_from_f64(alpha),
            m2: vec![T::zero(); n],
            m4: vec![T::zero(); n],
            cross: Mat::zeros(n, n),
            observed: 0,
        }
    }

    /// Output dimensionality n.
    pub fn n(&self) -> usize {
        self.m2.len()
    }

    /// Observations folded in so far.
    pub fn observed(&self) -> u64 {
        self.observed
    }

    /// Fold one output sample `y` (length n) into the estimates. The first
    /// observation primes every estimate directly (the AGC idiom) so
    /// startup is not a huge transient from zero.
    pub fn update(&mut self, y: &[T]) {
        let n = self.m2.len();
        assert_eq!(y.len(), n, "moment tracker dimensionality mismatch");
        let prime = self.observed == 0;
        let a = self.alpha;
        let one_minus = T::one() - a;
        for i in 0..n {
            let yi = y[i];
            let y2 = yi * yi;
            let y4 = y2 * y2;
            if prime {
                self.m2[i] = y2;
                self.m4[i] = y4;
            } else {
                self.m2[i] = one_minus * self.m2[i] + a * y2;
                self.m4[i] = one_minus * self.m4[i] + a * y4;
            }
            // Upper triangle + mirror: each (i, j) product computed once.
            for j in i..n {
                let prod = yi * y[j];
                let c = if prime {
                    prod
                } else {
                    one_minus * self.cross[(i, j)] + a * prod
                };
                self.cross[(i, j)] = c;
                if j != i {
                    self.cross[(j, i)] = c;
                }
            }
        }
        self.observed += 1;
    }

    /// Serialize the tracked estimates (detach-to-disk; `n` and `alpha`
    /// are config-derived at rebuild time). State widens to f64 bits,
    /// losslessly for both shipped precisions.
    pub fn save_state(&self, w: &mut crate::snapshot::SnapWriter) {
        let widen = |v: &Vec<T>| v.iter().map(|x| x.scalar_to_f64()).collect::<Vec<f64>>();
        w.put_f64_slice(&widen(&self.m2));
        w.put_f64_slice(&widen(&self.m4));
        w.put_mat(&self.cross);
        w.put_u64(self.observed);
    }

    /// Rehydrate the state written by [`save_state`](Self::save_state).
    pub fn load_state(&mut self, r: &mut crate::snapshot::SnapReader<'_>) -> anyhow::Result<()> {
        let narrow = |v: Vec<f64>| v.into_iter().map(T::scalar_from_f64).collect::<Vec<T>>();
        let m2 = narrow(r.get_f64_vec()?);
        let m4 = narrow(r.get_f64_vec()?);
        let cross: Mat<T> = r.get_mat()?;
        anyhow::ensure!(
            m2.len() == self.m2.len() && m4.len() == self.m4.len(),
            "snapshot moment tracker has {} channel(s), session expects {}",
            m2.len(),
            self.m2.len()
        );
        anyhow::ensure!(
            cross.shape() == self.cross.shape(),
            "snapshot cross-moment matrix shape mismatch"
        );
        self.m2 = m2;
        self.m4 = m4;
        self.cross = cross;
        self.observed = r.get_u64()?;
        Ok(())
    }

    /// EW `E[y_i²]`.
    pub fn variance(&self, i: usize) -> T {
        self.m2[i]
    }

    /// EW `E[y_i⁴]`.
    pub fn fourth_moment(&self, i: usize) -> T {
        self.m4[i]
    }

    /// The tracked second-moment matrix `Ĉ = EW[y yᵀ]`.
    pub fn cross(&self) -> &Mat<T> {
        &self.cross
    }

    /// Normalized fourth moment, averaged over channels:
    /// `mean_i(E[y_i⁴] / E[y_i²]²)` — scale-invariant, equals `kurtosis+3`
    /// for unit-variance channels. This is the "high-order data moment"
    /// the governor's learning-rate floor scales inversely with.
    pub fn normalized_fourth_moment(&self) -> f64 {
        if self.observed == 0 {
            return 3.0; // Gaussian prior until data arrives.
        }
        let n = self.m2.len();
        let mut acc = 0.0;
        for i in 0..n {
            let v = self.m2[i].scalar_to_f64().max(1e-12);
            acc += self.m4[i].scalar_to_f64() / (v * v);
        }
        acc / n as f64
    }

    /// Excess kurtosis of channel `i`: `E[y_i⁴]/E[y_i²]² − 3`.
    pub fn kurtosis_excess(&self, i: usize) -> f64 {
        let v = self.m2[i].scalar_to_f64().max(1e-12);
        self.m4[i].scalar_to_f64() / (v * v) - 3.0
    }

    /// Residual-whiteness statistic: `‖Ĉ − I‖_F / n` — the RMS deviation
    /// of the tracked second-moment matrix from the identity. At a
    /// separating point with unit-variance outputs this fluctuates near
    /// zero; under mixing drift the outputs decorrelate from the identity
    /// and the statistic rises. This is the [`super::DriftDetector`]'s
    /// input.
    pub fn whiteness_residual(&self) -> f64 {
        let n = self.m2.len();
        let mut acc = 0.0;
        for i in 0..n {
            for j in 0..n {
                let target = if i == j { 1.0 } else { 0.0 };
                let d = self.cross[(i, j)].scalar_to_f64() - target;
                acc += d * d;
            }
        }
        (acc / (n * n) as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_input_converges_to_exact_moments() {
        let mut tr = MomentTracker::<f64>::new(2, 0.05);
        for _ in 0..2000 {
            tr.update(&[1.0, -1.0]);
        }
        assert!((tr.variance(0) - 1.0).abs() < 1e-9);
        assert!((tr.fourth_moment(1) - 1.0).abs() < 1e-9);
        assert!((tr.cross()[(0, 1)] + 1.0).abs() < 1e-9);
        assert!((tr.cross()[(1, 0)] + 1.0).abs() < 1e-9);
        // C = [[1,-1],[-1,1]] → C − I = [[0,-1],[-1,0]] → RMS = sqrt(2/4).
        assert!((tr.whiteness_residual() - (0.5f64).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn first_observation_primes() {
        let mut tr = MomentTracker::<f64>::new(2, 0.01);
        tr.update(&[2.0, 0.5]);
        assert_eq!(tr.observed(), 1);
        assert_eq!(tr.variance(0), 4.0);
        assert_eq!(tr.fourth_moment(0), 16.0);
        assert_eq!(tr.cross()[(0, 1)], 1.0);
    }

    #[test]
    fn alternating_white_pair_has_small_residual() {
        // y alternating between (√2, 0) and (0, √2): time-average of y yᵀ
        // is the identity, so the smoothed residual settles low.
        let s = 2f64.sqrt();
        let mut tr = MomentTracker::<f64>::new(2, 0.01);
        for t in 0..20_000 {
            if t % 2 == 0 {
                tr.update(&[s, 0.0]);
            } else {
                tr.update(&[0.0, s]);
            }
        }
        assert!(
            tr.whiteness_residual() < 0.02,
            "residual {} for a white stream",
            tr.whiteness_residual()
        );
    }

    #[test]
    fn normalized_fourth_moment_is_scale_invariant() {
        let mut a = MomentTracker::<f64>::new(1, 0.05);
        let mut b = MomentTracker::<f64>::new(1, 0.05);
        for t in 0..5000 {
            let v = if t % 2 == 0 { 1.0 } else { -0.5 };
            a.update(&[v]);
            b.update(&[10.0 * v]);
        }
        assert!((a.normalized_fourth_moment() - b.normalized_fourth_moment()).abs() < 1e-6);
        // Rademacher-like ±1 stream: m4/m2² = 1 (maximally sub-Gaussian).
        let mut r = MomentTracker::<f64>::new(1, 0.05);
        for t in 0..5000 {
            r.update(&[if t % 2 == 0 { 1.0 } else { -1.0 }]);
        }
        assert!((r.normalized_fourth_moment() - 1.0).abs() < 1e-9);
        assert!((r.kurtosis_excess(0) + 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_tracker_reports_gaussian_prior() {
        let tr = MomentTracker::<f64>::new(3, 0.1);
        assert_eq!(tr.normalized_fourth_moment(), 3.0);
        assert_eq!(tr.observed(), 0);
    }

    #[test]
    fn f32_instantiation_tracks_like_f64() {
        let mut t64 = MomentTracker::<f64>::new(2, 0.02);
        let mut t32 = MomentTracker::<f32>::new(2, 0.02);
        let mut rng = crate::signal::Pcg32::seed(9);
        for _ in 0..3000 {
            let y = [rng.normal(), rng.normal()];
            t64.update(&y);
            t32.update(&[y[0] as f32, y[1] as f32]);
        }
        assert!((t64.whiteness_residual() - t32.whiteness_residual()).abs() < 1e-3);
        assert!(
            (t64.normalized_fourth_moment() - t32.normalized_fourth_moment()).abs() < 1e-2
        );
    }

    #[test]
    #[should_panic(expected = "dimensionality")]
    fn wrong_dim_panics() {
        let mut tr = MomentTracker::<f64>::new(2, 0.1);
        tr.update(&[1.0]);
    }
}
