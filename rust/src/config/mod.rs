//! Experiment configuration: a TOML-subset parser plus typed experiment
//! configs (stand-in for `serde` + `toml`, unavailable offline).
//!
//! Supported syntax — enough for experiment files, intentionally nothing
//! more: `[section.subsection]` headers, `key = value` with string,
//! integer, float, boolean and flat arrays, `#` comments.

mod parse;
mod types;

pub use parse::{parse, ParseError, Value};
pub use types::{
    AdaptConfig, EngineKind, ExperimentConfig, HubScenario, OptimizerConfig, OptimizerKind,
    PlacementKind, Precision, SessionSpec, SignalConfig,
};
