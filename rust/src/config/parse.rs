//! TOML-subset parser. See module docs in `config/mod.rs` for the grammar.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }

    /// Float accessor; integers widen implicitly (TOML-style `mu = 1`).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse error with 1-based line number.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

fn err(line: usize, message: impl Into<String>) -> ParseError {
    ParseError { line, message: message.into() }
}

/// Parse a TOML-subset document into a flat `section.key -> Value` map.
///
/// Keys in the root (before any section header) are stored without a
/// prefix; keys under `[a.b]` as `a.b.key`.
pub fn parse(text: &str) -> Result<BTreeMap<String, Value>, ParseError> {
    let mut out = BTreeMap::new();
    let mut section = String::new();

    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err(lineno, "empty section name"));
            }
            if !name.chars().all(|c| c.is_alphanumeric() || c == '.' || c == '_' || c == '-') {
                return Err(err(lineno, format!("invalid section name '{name}'")));
            }
            section = name.to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(lineno, format!("expected 'key = value', got '{line}'")))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        let value = parse_value(line[eq + 1..].trim(), lineno)?;
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        if out.insert(full.clone(), value).is_some() {
            return Err(err(lineno, format!("duplicate key '{full}'")));
        }
    }
    Ok(out)
}

/// Strip a `#` comment, respecting double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, lineno: usize) -> Result<Value, ParseError> {
    if text.is_empty() {
        return Err(err(lineno, "missing value"));
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        if inner.contains('"') {
            return Err(err(lineno, "embedded quotes not supported"));
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(rest) = text.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if part.starts_with('[') {
                return Err(err(lineno, "nested arrays not supported"));
            }
            items.push(parse_value(part, lineno)?);
        }
        return Ok(Value::Array(items));
    }
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    // Numbers: int first (no '.', 'e'), then float.
    if !text.contains('.') && !text.contains(['e', 'E']) {
        if let Ok(v) = text.parse::<i64>() {
            return Ok(Value::Int(v));
        }
    }
    if let Ok(v) = text.parse::<f64>() {
        return Ok(Value::Float(v));
    }
    Err(err(lineno, format!("cannot parse value '{text}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let doc = r#"
            name = "exp1"          # a comment
            iterations = 4166
            mu = 0.01
            adaptive = true
        "#;
        let m = parse(doc).unwrap();
        assert_eq!(m["name"], Value::Str("exp1".into()));
        assert_eq!(m["iterations"], Value::Int(4166));
        assert_eq!(m["mu"], Value::Float(0.01));
        assert_eq!(m["adaptive"], Value::Bool(true));
    }

    #[test]
    fn parses_sections_and_arrays() {
        let doc = r#"
            [optimizer.smbgd]
            gamma = 0.5
            dims = [4, 2]
            names = ["a", "b"]
        "#;
        let m = parse(doc).unwrap();
        assert_eq!(m["optimizer.smbgd.gamma"], Value::Float(0.5));
        assert_eq!(
            m["optimizer.smbgd.dims"],
            Value::Array(vec![Value::Int(4), Value::Int(2)])
        );
        assert_eq!(
            m["optimizer.smbgd.names"].as_array().unwrap()[1].as_str(),
            Some("b")
        );
    }

    #[test]
    fn int_widens_to_float() {
        let m = parse("mu = 1").unwrap();
        assert_eq!(m["mu"].as_float(), Some(1.0));
    }

    #[test]
    fn scientific_notation() {
        let m = parse("omega = 1e-3").unwrap();
        assert_eq!(m["omega"].as_float(), Some(1e-3));
    }

    #[test]
    fn negative_numbers() {
        let m = parse("a = -3\nb = -0.5").unwrap();
        assert_eq!(m["a"].as_int(), Some(-3));
        assert_eq!(m["b"].as_float(), Some(-0.5));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let m = parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(m["tag"].as_str(), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn unterminated_string_rejected() {
        assert!(parse("a = \"oops").is_err());
    }

    #[test]
    fn unterminated_section_rejected() {
        assert!(parse("[sec").is_err());
    }

    #[test]
    fn empty_array() {
        let m = parse("a = []").unwrap();
        assert_eq!(m["a"], Value::Array(vec![]));
    }

    #[test]
    fn nested_array_rejected() {
        assert!(parse("a = [[1], [2]]").is_err());
    }
}
