//! Typed experiment configuration, built from the parsed key/value map.

use super::parse::{parse, Value};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Which optimizer drives the separation-matrix updates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    /// Vanilla EASI (Fig. 1): per-sample SGD.
    Sgd,
    /// The paper's contribution (Fig. 2 / Eq. 1).
    Smbgd,
    /// Plain mini-batch GD baseline (§IV discussion).
    Mbgd,
}

impl OptimizerKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "sgd" => Self::Sgd,
            "smbgd" => Self::Smbgd,
            "mbgd" => Self::Mbgd,
            other => bail!("unknown optimizer '{other}' (expected sgd|smbgd|mbgd)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Sgd => "sgd",
            Self::Smbgd => "smbgd",
            Self::Mbgd => "mbgd",
        }
    }
}

/// Which execution engine applies the updates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-Rust hot path (`ica::*`).
    Native,
    /// AOT-compiled JAX/Pallas artifacts via PJRT (`runtime::*`).
    Pjrt,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "native" => Self::Native,
            "pjrt" => Self::Pjrt,
            other => bail!("unknown engine '{other}' (expected native|pjrt)"),
        })
    }
}

/// Arithmetic precision of a session's request path.
///
/// `f64` is the bit-exact reference (trajectory pins, parity oracles);
/// `f32` is the paper's 32-bit hardware datapath run in software — the
/// whole update pipeline (gradient, accumulator, B) stays in single
/// precision, pinned to the f64 reference by tolerance/Amari-parity tests
/// rather than bitwise. A hub can mix precisions across tenants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Single precision — the paper's FPGA datapath width.
    F32,
    /// Double precision — the bit-exact software reference (default).
    F64,
}

impl Precision {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Self::F32,
            "f64" => Self::F64,
            other => bail!("unknown precision '{other}' (expected f32|f64)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::F32 => "f32",
            Self::F64 => "f64",
        }
    }
}

/// Optimizer hyperparameters (paper §IV notation).
#[derive(Clone, Copy, Debug)]
pub struct OptimizerConfig {
    pub kind: OptimizerKind,
    /// Learning rate μ.
    pub mu: f64,
    /// Cross-batch momentum γ (SMBGD only).
    pub gamma: f64,
    /// Intra-batch decay β (SMBGD only).
    pub beta: f64,
    /// Mini-batch size P (SMBGD / MBGD).
    pub p: usize,
}

impl Default for OptimizerConfig {
    fn default() -> Self {
        Self { kind: OptimizerKind::Smbgd, mu: 0.002, gamma: 0.5, beta: 0.9, p: 8 }
    }
}

/// Signal-generation settings.
#[derive(Clone, Debug)]
pub struct SignalConfig {
    /// Source bank: "sub_gaussian" | "eeg".
    pub bank: String,
    /// Mixing model: "static" | "rotating" | "switching".
    pub mixing: String,
    /// Rotating-model angular velocity (rad/sample).
    pub omega: f64,
    /// Switching-model segment length (samples).
    pub period: u64,
    /// Condition-number cap for random mixing draws.
    pub max_cond: f64,
}

impl Default for SignalConfig {
    fn default() -> Self {
        Self {
            bank: "sub_gaussian".into(),
            mixing: "static".into(),
            omega: 1e-4,
            period: 50_000,
            max_cond: 10.0,
        }
    }
}

/// Full experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    /// Mixture dimensionality m.
    pub m: usize,
    /// Source/output dimensionality n.
    pub n: usize,
    pub seed: u64,
    /// Total training samples to stream.
    pub samples: usize,
    /// Amari-index threshold declaring convergence.
    pub convergence_threshold: f64,
    pub optimizer: OptimizerConfig,
    pub signal: SignalConfig,
    pub engine: EngineKind,
    /// Request-path arithmetic precision (native engine only).
    pub precision: Precision,
    /// Directory holding the AOT artifacts (PJRT engine).
    pub artifacts_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            name: "default".into(),
            m: 4,
            n: 2,
            seed: 0,
            samples: 100_000,
            convergence_threshold: 0.05,
            optimizer: OptimizerConfig::default(),
            signal: SignalConfig::default(),
            engine: EngineKind::Native,
            precision: Precision::F64,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl ExperimentConfig {
    /// Parse from TOML-subset text; unknown keys are rejected to catch
    /// typos in experiment files.
    pub fn from_toml(text: &str) -> Result<Self> {
        let map = parse(text).context("parsing experiment config")?;
        Self::from_map(&map)
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config file {path}"))?;
        Self::from_toml(&text)
    }

    fn from_map(map: &BTreeMap<String, Value>) -> Result<Self> {
        let mut cfg = Self::default();
        for (key, value) in map {
            let k = key.as_str();
            match k {
                "name" => cfg.name = want_str(k, value)?,
                "m" => cfg.m = want_usize(k, value)?,
                "n" => cfg.n = want_usize(k, value)?,
                "seed" => cfg.seed = want_usize(k, value)? as u64,
                "samples" => cfg.samples = want_usize(k, value)?,
                "convergence_threshold" => cfg.convergence_threshold = want_float(k, value)?,
                "engine" => cfg.engine = EngineKind::parse(&want_str(k, value)?)?,
                "precision" => cfg.precision = Precision::parse(&want_str(k, value)?)?,
                "artifacts_dir" => cfg.artifacts_dir = want_str(k, value)?,
                "optimizer.kind" => {
                    cfg.optimizer.kind = OptimizerKind::parse(&want_str(k, value)?)?
                }
                "optimizer.mu" => cfg.optimizer.mu = want_float(k, value)?,
                "optimizer.gamma" => cfg.optimizer.gamma = want_float(k, value)?,
                "optimizer.beta" => cfg.optimizer.beta = want_float(k, value)?,
                "optimizer.p" => cfg.optimizer.p = want_usize(k, value)?,
                "signal.bank" => cfg.signal.bank = want_str(k, value)?,
                "signal.mixing" => cfg.signal.mixing = want_str(k, value)?,
                "signal.omega" => cfg.signal.omega = want_float(k, value)?,
                "signal.period" => cfg.signal.period = want_usize(k, value)? as u64,
                "signal.max_cond" => cfg.signal.max_cond = want_float(k, value)?,
                other => bail!("unknown config key '{other}'"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Check cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        if self.n == 0 || self.m < self.n {
            bail!("need m >= n >= 1, got m={} n={}", self.m, self.n);
        }
        if !(self.optimizer.mu > 0.0 && self.optimizer.mu < 1.0) {
            bail!("mu must be in (0, 1), got {}", self.optimizer.mu);
        }
        if !(0.0..=1.0).contains(&self.optimizer.gamma) {
            bail!("gamma must be in [0, 1], got {}", self.optimizer.gamma);
        }
        if !(0.0..=1.0).contains(&self.optimizer.beta) {
            bail!("beta must be in (0, 1], got {}", self.optimizer.beta);
        }
        if self.optimizer.p == 0 {
            bail!("mini-batch size p must be >= 1");
        }
        match self.signal.bank.as_str() {
            "sub_gaussian" | "eeg" => {}
            other => bail!("unknown signal.bank '{other}'"),
        }
        match self.signal.mixing.as_str() {
            "static" | "rotating" | "switching" => {}
            other => bail!("unknown signal.mixing '{other}'"),
        }
        if self.engine == EngineKind::Pjrt && self.precision == Precision::F32 {
            bail!(
                "precision = \"f32\" requires the native engine (PJRT artifacts fix their dtype)"
            );
        }
        Ok(())
    }
}

/// A hub scenario: a fleet of separation sessions derived from one base
/// experiment config, plus the hub topology (session count, shard count,
/// per-session mixing kinds). Parsed from the same TOML subset; base
/// experiment keys sit at their usual places and hub keys under `[hub]`:
///
/// ```text
/// samples = 20000                     # base keys apply to every session
///
/// [optimizer]
/// mu = 0.004
///
/// [hub]
/// sessions = 8
/// shards = 2
/// channel_capacity = 4096             # per-shard, in samples
/// mixing = ["static", "rotating", "switching"]  # cycled by session id
/// seed_stride = 1
/// ```
#[derive(Clone, Debug)]
pub struct HubScenario {
    /// Number of concurrent sessions.
    pub sessions: usize,
    /// Worker shards the sessions are multiplexed onto.
    pub shards: usize,
    /// Per-shard ingest channel capacity in samples.
    pub channel_capacity: usize,
    /// Mixing kinds cycled across sessions (`static|rotating|switching`);
    /// empty inherits the base config's mixing for every session.
    pub mixing: Vec<String>,
    /// Precisions cycled across sessions (`f32|f64`); empty inherits the
    /// base config's precision for every session. This is how one
    /// `serve-many` process runs f32 and f64 tenants side by side.
    pub precision: Vec<Precision>,
    /// Session `i` streams with seed `base.seed + i * seed_stride`.
    pub seed_stride: u64,
    /// Template every session config derives from.
    pub base: ExperimentConfig,
}

impl Default for HubScenario {
    fn default() -> Self {
        Self {
            sessions: 8,
            shards: 2,
            channel_capacity: 4096,
            mixing: Vec::new(),
            precision: Vec::new(),
            seed_stride: 1,
            base: ExperimentConfig::default(),
        }
    }
}

impl HubScenario {
    /// Parse from TOML-subset text; unknown keys are rejected.
    pub fn from_toml(text: &str) -> Result<Self> {
        let map = parse(text).context("parsing hub scenario")?;
        let mut scenario = Self::default();
        let mut base_map = BTreeMap::new();
        for (key, value) in map {
            match key.as_str() {
                "hub.sessions" => scenario.sessions = want_usize(&key, &value)?,
                "hub.shards" => scenario.shards = want_usize(&key, &value)?,
                "hub.channel_capacity" => {
                    scenario.channel_capacity = want_usize(&key, &value)?
                }
                "hub.seed_stride" => scenario.seed_stride = want_usize(&key, &value)? as u64,
                "hub.mixing" => scenario.mixing = want_str_list(&key, &value)?,
                "hub.precision" => {
                    scenario.precision = want_str_list(&key, &value)?
                        .iter()
                        .map(|s| Precision::parse(s.as_str()))
                        .collect::<Result<Vec<_>>>()?
                }
                k if k.starts_with("hub.") => bail!("unknown config key '{k}'"),
                _ => {
                    base_map.insert(key, value);
                }
            }
        }
        scenario.base = ExperimentConfig::from_map(&base_map)?;
        scenario.validate()?;
        Ok(scenario)
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading hub scenario file {path}"))?;
        Self::from_toml(&text)
    }

    /// Check hub-level invariants (per-session configs are validated again
    /// by the hub itself).
    pub fn validate(&self) -> Result<()> {
        if self.sessions == 0 {
            bail!("hub.sessions must be >= 1");
        }
        if self.shards == 0 {
            bail!("hub.shards must be >= 1");
        }
        for m in &self.mixing {
            match m.as_str() {
                "static" | "rotating" | "switching" => {}
                other => bail!("unknown hub.mixing kind '{other}'"),
            }
        }
        // Same early rejection `ExperimentConfig::validate` gives the
        // non-cycled form, so serve-many fails at config time rather than
        // inside session-0 engine construction.
        if self.base.engine == EngineKind::Pjrt && self.precision.contains(&Precision::F32) {
            bail!("hub.precision includes \"f32\" but the engine is pjrt (f32 needs native)");
        }
        self.base.validate()
    }

    /// Materialize session `id`'s config: base + per-session seed, mixing
    /// kind and precision (cycled), and name suffix.
    pub fn session_config(&self, id: usize) -> ExperimentConfig {
        let mut cfg = self.base.clone();
        cfg.seed = self.base.seed.wrapping_add((id as u64).wrapping_mul(self.seed_stride));
        if !self.mixing.is_empty() {
            cfg.signal.mixing = self.mixing[id % self.mixing.len()].clone();
        }
        if !self.precision.is_empty() {
            cfg.precision = self.precision[id % self.precision.len()];
        }
        cfg.name = format!("{}-{id}", self.base.name);
        cfg
    }

    /// Materialize every session config.
    pub fn session_configs(&self) -> Vec<ExperimentConfig> {
        (0..self.sessions).map(|id| self.session_config(id)).collect()
    }
}

fn want_str(key: &str, v: &Value) -> Result<String> {
    v.as_str().map(str::to_string).with_context(|| format!("'{key}' must be a string"))
}

fn want_float(key: &str, v: &Value) -> Result<f64> {
    v.as_float().with_context(|| format!("'{key}' must be a number"))
}

fn want_usize(key: &str, v: &Value) -> Result<usize> {
    let i = v.as_int().with_context(|| format!("'{key}' must be an integer"))?;
    if i < 0 {
        bail!("'{key}' must be non-negative, got {i}");
    }
    Ok(i as usize)
}

/// Accept either a single string or a flat array of strings.
fn want_str_list(key: &str, v: &Value) -> Result<Vec<String>> {
    match v {
        Value::Str(s) => Ok(vec![s.clone()]),
        Value::Array(items) => items
            .iter()
            .map(|it| {
                it.as_str()
                    .map(str::to_string)
                    .with_context(|| format!("'{key}' must contain strings"))
            })
            .collect(),
        _ => bail!("'{key}' must be a string or an array of strings"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn full_round_trip() {
        let doc = r#"
            name = "table1"
            m = 4
            n = 2
            seed = 7
            samples = 50000
            convergence_threshold = 0.05
            engine = "native"

            [optimizer]
            kind = "smbgd"
            mu = 0.004
            gamma = 0.6
            beta = 0.95
            p = 16

            [signal]
            bank = "sub_gaussian"
            mixing = "rotating"
            omega = 2e-4
        "#;
        let cfg = ExperimentConfig::from_toml(doc).unwrap();
        assert_eq!(cfg.name, "table1");
        assert_eq!((cfg.m, cfg.n), (4, 2));
        assert_eq!(cfg.optimizer.kind, OptimizerKind::Smbgd);
        assert_eq!(cfg.optimizer.p, 16);
        assert_eq!(cfg.signal.mixing, "rotating");
        assert_eq!(cfg.signal.omega, 2e-4);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(ExperimentConfig::from_toml("typo_key = 1").is_err());
    }

    #[test]
    fn m_less_than_n_rejected() {
        assert!(ExperimentConfig::from_toml("m = 2\nn = 4").is_err());
    }

    #[test]
    fn bad_optimizer_rejected() {
        let doc = "[optimizer]\nkind = \"adam\"";
        assert!(ExperimentConfig::from_toml(doc).is_err());
    }

    #[test]
    fn bad_mu_rejected() {
        let doc = "[optimizer]\nmu = 1.5";
        assert!(ExperimentConfig::from_toml(doc).is_err());
    }

    #[test]
    fn hub_scenario_round_trip() {
        let doc = r#"
            name = "fleet"
            samples = 9000
            seed = 100

            [optimizer]
            mu = 0.004

            [hub]
            sessions = 6
            shards = 3
            channel_capacity = 1024
            mixing = ["static", "rotating"]
            seed_stride = 10
        "#;
        let sc = HubScenario::from_toml(doc).unwrap();
        assert_eq!((sc.sessions, sc.shards, sc.channel_capacity), (6, 3, 1024));
        assert_eq!(sc.base.samples, 9000);
        let cfgs = sc.session_configs();
        assert_eq!(cfgs.len(), 6);
        assert_eq!(cfgs[0].seed, 100);
        assert_eq!(cfgs[3].seed, 130);
        assert_eq!(cfgs[0].signal.mixing, "static");
        assert_eq!(cfgs[1].signal.mixing, "rotating");
        assert_eq!(cfgs[2].signal.mixing, "static");
        assert_eq!(cfgs[5].name, "fleet-5");
        for c in &cfgs {
            c.validate().unwrap();
        }
    }

    #[test]
    fn hub_scenario_single_mixing_string() {
        let sc = HubScenario::from_toml("[hub]\nmixing = \"switching\"").unwrap();
        assert_eq!(sc.mixing, vec!["switching".to_string()]);
        assert_eq!(sc.session_config(4).signal.mixing, "switching");
    }

    #[test]
    fn hub_scenario_empty_mixing_inherits_base() {
        let sc = HubScenario::from_toml("[signal]\nmixing = \"rotating\"").unwrap();
        assert_eq!(sc.session_config(2).signal.mixing, "rotating");
    }

    #[test]
    fn hub_scenario_rejects_bad_keys_and_values() {
        assert!(HubScenario::from_toml("[hub]\nsessions = 0").is_err());
        assert!(HubScenario::from_toml("[hub]\nshards = 0").is_err());
        assert!(HubScenario::from_toml("[hub]\nmixing = \"warp\"").is_err());
        assert!(HubScenario::from_toml("[hub]\ntypo = 1").is_err());
        assert!(HubScenario::from_toml("typo = 1").is_err(), "base keys still strict");
    }

    #[test]
    fn engine_parse() {
        assert_eq!(EngineKind::parse("pjrt").unwrap(), EngineKind::Pjrt);
        assert!(EngineKind::parse("gpu").is_err());
    }

    #[test]
    fn precision_parse_round_trip() {
        for p in [Precision::F32, Precision::F64] {
            assert_eq!(Precision::parse(p.name()).unwrap(), p);
        }
        assert!(Precision::parse("f16").is_err());
    }

    #[test]
    fn precision_config_key() {
        let cfg = ExperimentConfig::from_toml("precision = \"f32\"").unwrap();
        assert_eq!(cfg.precision, Precision::F32);
        assert_eq!(ExperimentConfig::default().precision, Precision::F64);
        assert!(ExperimentConfig::from_toml("precision = \"f16\"").is_err());
    }

    #[test]
    fn f32_requires_native_engine() {
        let doc = "engine = \"pjrt\"\nprecision = \"f32\"";
        assert!(ExperimentConfig::from_toml(doc).is_err());
        let doc = "engine = \"native\"\nprecision = \"f32\"";
        assert!(ExperimentConfig::from_toml(doc).is_ok());
    }

    #[test]
    fn hub_scenario_cycles_precisions() {
        let sc = HubScenario::from_toml("[hub]\nprecision = [\"f32\", \"f64\"]").unwrap();
        assert_eq!(sc.session_config(0).precision, Precision::F32);
        assert_eq!(sc.session_config(1).precision, Precision::F64);
        assert_eq!(sc.session_config(4).precision, Precision::F32);
        // Single string form and inheritance.
        let sc = HubScenario::from_toml("[hub]\nprecision = \"f32\"").unwrap();
        assert_eq!(sc.session_config(3).precision, Precision::F32);
        let sc = HubScenario::from_toml("precision = \"f32\"").unwrap();
        assert_eq!(sc.session_config(2).precision, Precision::F32);
        assert!(HubScenario::from_toml("[hub]\nprecision = \"f16\"").is_err());
        // Cycled f32 with a pjrt base engine is rejected at config time,
        // matching the non-cycled check in ExperimentConfig::validate.
        let doc = "engine = \"pjrt\"\n[hub]\nprecision = [\"f32\", \"f64\"]";
        assert!(HubScenario::from_toml(doc).is_err());
    }
}
