//! Typed experiment configuration, built from the parsed key/value map.

use super::parse::{parse, Value};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Which optimizer drives the separation-matrix updates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    /// Vanilla EASI (Fig. 1): per-sample SGD.
    Sgd,
    /// The paper's contribution (Fig. 2 / Eq. 1).
    Smbgd,
    /// Plain mini-batch GD baseline (§IV discussion).
    Mbgd,
}

impl OptimizerKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "sgd" => Self::Sgd,
            "smbgd" => Self::Smbgd,
            "mbgd" => Self::Mbgd,
            other => bail!("unknown optimizer '{other}' (expected sgd|smbgd|mbgd)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Sgd => "sgd",
            Self::Smbgd => "smbgd",
            Self::Mbgd => "mbgd",
        }
    }
}

/// Which execution engine applies the updates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-Rust hot path (`ica::*`).
    Native,
    /// AOT-compiled JAX/Pallas artifacts via PJRT (`runtime::*`).
    Pjrt,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "native" => Self::Native,
            "pjrt" => Self::Pjrt,
            other => bail!("unknown engine '{other}' (expected native|pjrt)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Native => "native",
            Self::Pjrt => "pjrt",
        }
    }
}

/// Arithmetic precision of a session's request path.
///
/// `f64` is the bit-exact reference (trajectory pins, parity oracles);
/// `f32` is the paper's 32-bit hardware datapath run in software — the
/// whole update pipeline (gradient, accumulator, B) stays in single
/// precision, pinned to the f64 reference by tolerance/Amari-parity tests
/// rather than bitwise. `q16`/`q32` are the predecessor hardware's
/// fixed-point Q-formats (`qfx::Fixed`): deterministic round-to-nearest-
/// even with saturating rails, parity-locked to the FPGA datapath model
/// and guarded by the saturation latch instead of non-finite checks. A
/// hub can mix precisions across tenants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Single precision — the paper's FPGA datapath width.
    F32,
    /// Double precision — the bit-exact software reference (default).
    F64,
    /// 16-bit fixed point (Q2.14) — the prior-work datapath width the
    /// paper argues against; served via `qfx::Q16`.
    Q16,
    /// 32-bit fixed point (Q4.28) — the wide fixed-point ablation point;
    /// served via `qfx::Q32`.
    Q32,
}

impl Precision {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Self::F32,
            "f64" => Self::F64,
            "q16" => Self::Q16,
            "q32" => Self::Q32,
            other => bail!("unknown precision '{other}' (expected f32|f64|q16|q32)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::F32 => "f32",
            Self::F64 => "f64",
            Self::Q16 => "q16",
            Self::Q32 => "q32",
        }
    }
}

/// Admission-time shard placement policy for the elastic serving plane.
///
/// `LeastLoaded` is the serving default: a new tenant lands on the shard
/// with the fewest active sessions (ties break toward the lowest shard
/// index), so capacity freed by departures is reused. `Modulo` keeps the
/// deterministic `id % shards` pinning of the batch hub — placement never
/// changes a session's *math* (every runner is self-contained), but
/// modulo keeps shard assignments byte-for-byte reproducible, which is
/// what the bit-exactness pins against the batch hub run under.
/// `CohortAffinity` steers cohort-eligible tenants toward shards already
/// hosting tenants with the same pool key, so compatible lanes actually
/// share fused tenant-major kernels (raising pool occupancy); everything
/// else falls back to the least-loaded rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementKind {
    /// Fewest active sessions wins; ties go to the lowest shard index.
    LeastLoaded,
    /// Deterministic `session_id % shards` (the batch hub's rule).
    Modulo,
    /// Shape-aware: co-locate tenants sharing a cohort pool key.
    CohortAffinity,
}

impl PlacementKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "least_loaded" => Self::LeastLoaded,
            "modulo" => Self::Modulo,
            "cohort_affinity" => Self::CohortAffinity,
            other => bail!(
                "unknown placement '{other}' (expected least_loaded|modulo|cohort_affinity)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::LeastLoaded => "least_loaded",
            Self::Modulo => "modulo",
            Self::CohortAffinity => "cohort_affinity",
        }
    }
}

/// Optimizer hyperparameters (paper §IV notation).
#[derive(Clone, Copy, Debug)]
pub struct OptimizerConfig {
    pub kind: OptimizerKind,
    /// Learning rate μ.
    pub mu: f64,
    /// Cross-batch momentum γ (SMBGD only).
    pub gamma: f64,
    /// Intra-batch decay β (SMBGD only).
    pub beta: f64,
    /// Mini-batch size P (SMBGD / MBGD).
    pub p: usize,
}

impl Default for OptimizerConfig {
    fn default() -> Self {
        Self { kind: OptimizerKind::Smbgd, mu: 0.002, gamma: 0.5, beta: 0.9, p: 8 }
    }
}

/// Signal-generation settings.
#[derive(Clone, Debug)]
pub struct SignalConfig {
    /// Source bank: "sub_gaussian" | "eeg".
    pub bank: String,
    /// Mixing model: "static" | "rotating" | "switching" | "switch_once"
    /// | "drift_onset" | "nan_burst".
    pub mixing: String,
    /// Rotating/drift-onset angular velocity (rad/sample).
    pub omega: f64,
    /// Switching-model segment length (samples).
    pub period: u64,
    /// Switch-once / drift-onset / nan-burst event sample index.
    pub switch_at: u64,
    /// Condition-number cap for random mixing draws.
    pub max_cond: f64,
}

impl Default for SignalConfig {
    fn default() -> Self {
        Self {
            bank: "sub_gaussian".into(),
            mixing: "static".into(),
            omega: 1e-4,
            period: 50_000,
            switch_at: 50_000,
            max_cond: 10.0,
        }
    }
}

/// Adaptive control plane settings (`rust/src/adapt`): the per-session
/// closed loop of moment tracker → drift detector → learning-rate
/// governor. Off by default — a disabled session is bit-identical to the
/// PR-3 coordinator.
#[derive(Clone, Copy, Debug)]
pub struct AdaptConfig {
    /// Enable the closed loop for this session.
    pub enabled: bool,
    /// Observe every `stride`-th sample (decimation bounds the hot-path
    /// overhead; the §Perf suite gates it).
    pub stride: usize,
    /// EW coefficient of the moment tracker (per observation).
    pub alpha: f64,
    /// Detector arms once the whiteness residual falls below this.
    pub armed_level: f64,
    /// Instantaneous residual at/above this → abrupt drift.
    pub abrupt_level: f64,
    /// Page–Hinkley insensitivity band δ.
    pub ph_delta: f64,
    /// Page–Hinkley alarm threshold λ.
    pub ph_lambda: f64,
    /// μ multiplier applied on a detected drift (≥ 1).
    pub boost: f64,
    /// Anneal time constant τ (samples).
    pub tau: f64,
    /// Inverse-moment floor constant: μ_floor = floor_c / m̂₄ (clamped).
    pub floor_c: f64,
    /// Lower clamp of the μ floor.
    pub floor_min: f64,
    /// Restore the last steady-state checkpoint (instead of the warm
    /// start) when a post-drift step diverges.
    pub rollback: bool,
}

impl Default for AdaptConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            stride: 4,
            alpha: 0.02,
            armed_level: 0.25,
            abrupt_level: 0.6,
            ph_delta: 0.04,
            ph_lambda: 3.0,
            boost: 2.0,
            tau: 4000.0,
            floor_c: 0.003,
            floor_min: 2e-4,
            rollback: true,
        }
    }
}

impl AdaptConfig {
    pub fn validate(&self) -> Result<()> {
        if self.stride == 0 {
            bail!("adapt.stride must be >= 1");
        }
        if !(self.alpha > 0.0 && self.alpha <= 1.0) {
            bail!("adapt.alpha must be in (0, 1], got {}", self.alpha);
        }
        if !(self.armed_level > 0.0 && self.armed_level < self.abrupt_level) {
            bail!(
                "need 0 < adapt.armed_level < adapt.abrupt_level, got {} / {}",
                self.armed_level,
                self.abrupt_level
            );
        }
        if self.ph_delta < 0.0 {
            bail!("adapt.ph_delta must be non-negative");
        }
        if self.ph_lambda <= 0.0 {
            bail!("adapt.ph_lambda must be positive");
        }
        if self.boost < 1.0 {
            bail!("adapt.boost must be >= 1, got {}", self.boost);
        }
        if self.tau <= 0.0 {
            bail!("adapt.tau must be positive");
        }
        if self.floor_c < 0.0 {
            bail!("adapt.floor_c must be non-negative");
        }
        let mu_max = crate::adapt::MU_MAX;
        if !(self.floor_min > 0.0 && self.floor_min <= mu_max) {
            bail!("adapt.floor_min must be in (0, {mu_max}], got {}", self.floor_min);
        }
        Ok(())
    }

    /// The schedule-space description of this configuration's governor law
    /// (the open-loop envelope; see `ica::MuSchedule::Adaptive`). The
    /// floor is capped at μ₀ exactly like `adapt::Governor::floor`, so
    /// micro-μ configurations (μ₀ below `floor_min`) describe a valid
    /// schedule instead of panicking its `validate`.
    pub fn schedule(&self, mu0: f64) -> crate::ica::MuSchedule {
        crate::ica::MuSchedule::Adaptive {
            mu0,
            boost: self.boost,
            tau: self.tau,
            floor_min: self.floor_min.min(mu0),
        }
    }
}

/// Full experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    /// Mixture dimensionality m.
    pub m: usize,
    /// Source/output dimensionality n.
    pub n: usize,
    pub seed: u64,
    /// Total training samples to stream.
    pub samples: usize,
    /// Amari-index threshold declaring convergence.
    pub convergence_threshold: f64,
    pub optimizer: OptimizerConfig,
    pub signal: SignalConfig,
    /// Adaptive control plane (drift detection + μ governor).
    pub adapt: AdaptConfig,
    pub engine: EngineKind,
    /// Request-path arithmetic precision (native engine only).
    pub precision: Precision,
    /// Directory holding the AOT artifacts (PJRT engine).
    pub artifacts_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            name: "default".into(),
            m: 4,
            n: 2,
            seed: 0,
            samples: 100_000,
            convergence_threshold: 0.05,
            optimizer: OptimizerConfig::default(),
            signal: SignalConfig::default(),
            adapt: AdaptConfig::default(),
            engine: EngineKind::Native,
            precision: Precision::F64,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl ExperimentConfig {
    /// Parse from TOML-subset text; unknown keys are rejected to catch
    /// typos in experiment files.
    pub fn from_toml(text: &str) -> Result<Self> {
        let map = parse(text).context("parsing experiment config")?;
        Self::from_map(&map)
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config file {path}"))?;
        Self::from_toml(&text)
    }

    fn from_map(map: &BTreeMap<String, Value>) -> Result<Self> {
        let mut cfg = Self::default();
        for (key, value) in map {
            let k = key.as_str();
            match k {
                "name" => cfg.name = want_str(k, value)?,
                "m" => cfg.m = want_usize(k, value)?,
                "n" => cfg.n = want_usize(k, value)?,
                "seed" => cfg.seed = want_usize(k, value)? as u64,
                "samples" => cfg.samples = want_usize(k, value)?,
                "convergence_threshold" => cfg.convergence_threshold = want_float(k, value)?,
                "engine" => cfg.engine = EngineKind::parse(&want_str(k, value)?)?,
                "precision" => cfg.precision = Precision::parse(&want_str(k, value)?)?,
                "artifacts_dir" => cfg.artifacts_dir = want_str(k, value)?,
                "optimizer.kind" => {
                    cfg.optimizer.kind = OptimizerKind::parse(&want_str(k, value)?)?
                }
                "optimizer.mu" => cfg.optimizer.mu = want_float(k, value)?,
                "optimizer.gamma" => cfg.optimizer.gamma = want_float(k, value)?,
                "optimizer.beta" => cfg.optimizer.beta = want_float(k, value)?,
                "optimizer.p" => cfg.optimizer.p = want_usize(k, value)?,
                "signal.bank" => cfg.signal.bank = want_str(k, value)?,
                "signal.mixing" => cfg.signal.mixing = want_str(k, value)?,
                "signal.omega" => cfg.signal.omega = want_float(k, value)?,
                "signal.period" => cfg.signal.period = want_usize(k, value)? as u64,
                "signal.switch_at" => cfg.signal.switch_at = want_usize(k, value)? as u64,
                "signal.max_cond" => cfg.signal.max_cond = want_float(k, value)?,
                "adapt.enabled" => cfg.adapt.enabled = want_bool(k, value)?,
                "adapt.stride" => cfg.adapt.stride = want_usize(k, value)?,
                "adapt.alpha" => cfg.adapt.alpha = want_float(k, value)?,
                "adapt.armed_level" => cfg.adapt.armed_level = want_float(k, value)?,
                "adapt.abrupt_level" => cfg.adapt.abrupt_level = want_float(k, value)?,
                "adapt.ph_delta" => cfg.adapt.ph_delta = want_float(k, value)?,
                "adapt.ph_lambda" => cfg.adapt.ph_lambda = want_float(k, value)?,
                "adapt.boost" => cfg.adapt.boost = want_float(k, value)?,
                "adapt.tau" => cfg.adapt.tau = want_float(k, value)?,
                "adapt.floor_c" => cfg.adapt.floor_c = want_float(k, value)?,
                "adapt.floor_min" => cfg.adapt.floor_min = want_float(k, value)?,
                "adapt.rollback" => cfg.adapt.rollback = want_bool(k, value)?,
                other => bail!("unknown config key '{other}'"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Check cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        if self.n == 0 || self.m < self.n {
            bail!("need m >= n >= 1, got m={} n={}", self.m, self.n);
        }
        if !(self.optimizer.mu > 0.0 && self.optimizer.mu < 1.0) {
            bail!("mu must be in (0, 1), got {}", self.optimizer.mu);
        }
        if !(0.0..=1.0).contains(&self.optimizer.gamma) {
            bail!("gamma must be in [0, 1], got {}", self.optimizer.gamma);
        }
        if !(0.0..=1.0).contains(&self.optimizer.beta) {
            bail!("beta must be in (0, 1], got {}", self.optimizer.beta);
        }
        if self.optimizer.p == 0 {
            bail!("mini-batch size p must be >= 1");
        }
        match self.signal.bank.as_str() {
            "sub_gaussian" | "eeg" => {}
            other => bail!("unknown signal.bank '{other}'"),
        }
        match self.signal.mixing.as_str() {
            "static" | "rotating" | "switching" | "switch_once" | "drift_onset"
            | "nan_burst" => {}
            other => bail!("unknown signal.mixing '{other}'"),
        }
        self.adapt.validate()?;
        if self.engine == EngineKind::Pjrt && self.precision != Precision::F64 {
            bail!(
                "precision = \"{}\" requires the native engine (PJRT artifacts fix their dtype)",
                self.precision.name()
            );
        }
        Ok(())
    }
}

/// A hub scenario: a fleet of separation sessions derived from one base
/// experiment config, plus the hub topology (session count, shard count,
/// per-session mixing kinds). Parsed from the same TOML subset; base
/// experiment keys sit at their usual places and hub keys under `[hub]`:
///
/// ```text
/// samples = 20000                     # base keys apply to every session
///
/// [optimizer]
/// mu = 0.004
///
/// [hub]
/// sessions = 8
/// shards = 2
/// channel_capacity = 4096             # per-shard, in samples
/// mixing = ["static", "rotating", "switching"]  # cycled by session id
/// seed_stride = 1
/// ```
#[derive(Clone, Debug)]
pub struct HubScenario {
    /// Number of concurrent sessions.
    pub sessions: usize,
    /// Worker shards the sessions are multiplexed onto.
    pub shards: usize,
    /// Per-shard ingest channel capacity in samples.
    pub channel_capacity: usize,
    /// Mixing kinds cycled across sessions (`static|rotating|switching`);
    /// empty inherits the base config's mixing for every session.
    pub mixing: Vec<String>,
    /// Precisions cycled across sessions (`f32|f64`); empty inherits the
    /// base config's precision for every session. This is how one
    /// `serve-many` process runs f32 and f64 tenants side by side.
    pub precision: Vec<Precision>,
    /// Adaptive-control enablement cycled across sessions (booleans);
    /// empty inherits the base config's `adapt.enabled` for every
    /// session. `hub.adapt = [true, false]` runs governed and fixed-μ
    /// tenants side by side.
    pub adapt: Vec<bool>,
    /// Session `i` streams with seed `base.seed + i * seed_stride`.
    pub seed_stride: u64,
    /// Admission-time shard placement (elastic serving plane).
    pub placement: PlacementKind,
    /// Step same-shape tenants together through tenant-major cohort
    /// kernels on the worker hot loop (bit-identical to per-session
    /// stepping; `false` forces the per-session path).
    pub cohort: bool,
    /// Churn schedule, arrivals: session `i` is admitted once the hub has
    /// ingested `i * arrive_stride` samples in aggregate (0 = everyone
    /// arrives up front — the static scenario).
    pub arrive_stride: u64,
    /// Churn schedule, departures: per-session early-departure points in
    /// the session's *own* sample count, cycled by session id like
    /// `mixing` (0 = stream to completion). `depart_at = [0, 20000]`
    /// makes every other tenant leave after 20k samples.
    pub depart_at: Vec<u64>,
    /// TCP listen address for the framed command/data plane
    /// (`hub.listen = "127.0.0.1:7700"`; port 0 picks an ephemeral
    /// port). `None` serves in-process only.
    pub listen: Option<String>,
    /// Durability root for detach-to-disk session snapshots
    /// (`hub.state_dir = "state/"`). `None` disables implicit-path
    /// durability.
    pub state_dir: Option<String>,
    /// Enable queue-pressure shard autoscaling
    /// (`hub.autoscale.enabled = true`).
    pub autoscale_enabled: bool,
    /// Autoscaler shard-count floor (`hub.autoscale.min_shards`).
    pub autoscale_min: usize,
    /// Autoscaler shard-count ceiling (`hub.autoscale.max_shards`).
    pub autoscale_max: usize,
    /// Mean-pressure spawn threshold (`hub.autoscale.high`).
    pub autoscale_high: f64,
    /// Mean-pressure retire threshold (`hub.autoscale.low`).
    pub autoscale_low: f64,
    /// Consecutive ticks a threshold must hold before the autoscaler
    /// acts (`hub.autoscale.sustain`).
    pub autoscale_sustain: usize,
    /// Crash-consistent background snapshot cadence in milliseconds
    /// (`hub.snapshot_every_ms`; needs `hub.state_dir`; 0 disables).
    pub snapshot_every_ms: u64,
    /// Supervisor respawns granted to each shard slot before it is
    /// declared failed (`hub.restart_budget`).
    pub restart_budget: usize,
    /// Template every session config derives from.
    pub base: ExperimentConfig,
}

impl Default for HubScenario {
    fn default() -> Self {
        Self {
            sessions: 8,
            shards: 2,
            channel_capacity: 4096,
            mixing: Vec::new(),
            precision: Vec::new(),
            adapt: Vec::new(),
            seed_stride: 1,
            placement: PlacementKind::LeastLoaded,
            cohort: true,
            arrive_stride: 0,
            depart_at: Vec::new(),
            listen: None,
            state_dir: None,
            autoscale_enabled: false,
            autoscale_min: 1,
            autoscale_max: 8,
            autoscale_high: 0.75,
            autoscale_low: 0.10,
            autoscale_sustain: 3,
            snapshot_every_ms: 0,
            restart_budget: 3,
            base: ExperimentConfig::default(),
        }
    }
}

/// One session's lifecycle plan inside a hub scenario: its experiment
/// config plus when it joins and (optionally) leaves the serving plane.
#[derive(Clone, Debug)]
pub struct SessionSpec {
    /// The session's materialized experiment config.
    pub cfg: ExperimentConfig,
    /// Admission threshold: attach once the hub's aggregate ingested
    /// sample count reaches this (0 = at start).
    pub arrive_at: u64,
    /// Early departure after this many of the session's own samples
    /// (0 = stream the full `cfg.samples`). Departure is a clean drain:
    /// the tenant's trajectory is exactly a run with this sample count.
    pub depart_at: u64,
}

impl SessionSpec {
    /// Samples this session will actually stream.
    pub fn effective_samples(&self) -> usize {
        if self.depart_at == 0 {
            self.cfg.samples
        } else {
            self.cfg.samples.min(self.depart_at as usize)
        }
    }
}

impl HubScenario {
    /// Parse from TOML-subset text; unknown keys are rejected.
    pub fn from_toml(text: &str) -> Result<Self> {
        let map = parse(text).context("parsing hub scenario")?;
        let mut scenario = Self::default();
        let mut base_map = BTreeMap::new();
        for (key, value) in map {
            match key.as_str() {
                "hub.sessions" => scenario.sessions = want_usize(&key, &value)?,
                "hub.shards" => scenario.shards = want_usize(&key, &value)?,
                "hub.channel_capacity" => {
                    scenario.channel_capacity = want_usize(&key, &value)?
                }
                "hub.seed_stride" => scenario.seed_stride = want_usize(&key, &value)? as u64,
                "hub.mixing" => scenario.mixing = want_str_list(&key, &value)?,
                "hub.precision" => {
                    scenario.precision = want_str_list(&key, &value)?
                        .iter()
                        .map(|s| Precision::parse(s.as_str()))
                        .collect::<Result<Vec<_>>>()?
                }
                "hub.adapt" => scenario.adapt = want_bool_list(&key, &value)?,
                "hub.placement" => {
                    scenario.placement = PlacementKind::parse(&want_str(&key, &value)?)?
                }
                "hub.cohort" => scenario.cohort = want_bool(&key, &value)?,
                "hub.arrive_stride" => {
                    scenario.arrive_stride = want_usize(&key, &value)? as u64
                }
                "hub.depart_at" => scenario.depart_at = want_usize_list(&key, &value)?,
                "hub.listen" => scenario.listen = Some(want_str(&key, &value)?),
                "hub.state_dir" => scenario.state_dir = Some(want_str(&key, &value)?),
                "hub.autoscale.enabled" => {
                    scenario.autoscale_enabled = want_bool(&key, &value)?
                }
                "hub.autoscale.min_shards" => {
                    scenario.autoscale_min = want_usize(&key, &value)?
                }
                "hub.autoscale.max_shards" => {
                    scenario.autoscale_max = want_usize(&key, &value)?
                }
                "hub.autoscale.high" => scenario.autoscale_high = want_float(&key, &value)?,
                "hub.autoscale.low" => scenario.autoscale_low = want_float(&key, &value)?,
                "hub.autoscale.sustain" => {
                    scenario.autoscale_sustain = want_usize(&key, &value)?
                }
                "hub.snapshot_every_ms" => {
                    scenario.snapshot_every_ms = want_usize(&key, &value)? as u64
                }
                "hub.restart_budget" => {
                    scenario.restart_budget = want_usize(&key, &value)?
                }
                k if k.starts_with("hub.") => bail!("unknown config key '{k}'"),
                _ => {
                    base_map.insert(key, value);
                }
            }
        }
        scenario.base = ExperimentConfig::from_map(&base_map)?;
        scenario.validate()?;
        Ok(scenario)
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading hub scenario file {path}"))?;
        Self::from_toml(&text)
    }

    /// Check hub-level invariants (per-session configs are validated again
    /// by the hub itself).
    pub fn validate(&self) -> Result<()> {
        if self.sessions == 0 && self.listen.is_none() {
            // A network server may start with an empty fleet — its tenants
            // arrive over the socket. A batch scenario may not.
            bail!("hub.sessions must be >= 1");
        }
        if self.shards == 0 {
            bail!("hub.shards must be >= 1");
        }
        for m in &self.mixing {
            match m.as_str() {
                "static" | "rotating" | "switching" | "switch_once" | "drift_onset"
                | "nan_burst" => {}
                other => bail!("unknown hub.mixing kind '{other}'"),
            }
        }
        // Same early rejection `ExperimentConfig::validate` gives the
        // non-cycled form, so serve-many fails at config time rather than
        // inside session-0 engine construction.
        if self.base.engine == EngineKind::Pjrt
            && self.precision.iter().any(|p| *p != Precision::F64)
        {
            bail!(
                "hub.precision includes a non-f64 entry but the engine is pjrt \
                 (f32/q16/q32 need native)"
            );
        }
        if let Some(listen) = &self.listen {
            if listen.is_empty() || !listen.contains(':') {
                bail!("hub.listen must be a host:port address, got '{listen}'");
            }
        }
        if let Some(dir) = &self.state_dir {
            if dir.is_empty() {
                bail!("hub.state_dir must be a non-empty path");
            }
        }
        if self.snapshot_every_ms != 0 && self.state_dir.is_none() {
            bail!(
                "hub.snapshot_every_ms = {} needs hub.state_dir to write background \
                 snapshots into",
                self.snapshot_every_ms
            );
        }
        if self.autoscale_enabled {
            if self.autoscale_min == 0 {
                bail!("hub.autoscale.min_shards must be >= 1");
            }
            if self.autoscale_min > self.autoscale_max {
                bail!(
                    "hub.autoscale.min_shards ({}) must not exceed max_shards ({})",
                    self.autoscale_min,
                    self.autoscale_max
                );
            }
            if !(self.autoscale_low >= 0.0
                && self.autoscale_high > self.autoscale_low
                && self.autoscale_high.is_finite())
            {
                bail!(
                    "hub.autoscale needs 0 <= low < high, got low = {} high = {}",
                    self.autoscale_low,
                    self.autoscale_high
                );
            }
            if self.autoscale_sustain == 0 {
                bail!("hub.autoscale.sustain must be >= 1");
            }
            if self.shards > self.autoscale_max {
                bail!(
                    "hub.shards ({}) exceeds hub.autoscale.max_shards ({})",
                    self.shards,
                    self.autoscale_max
                );
            }
        }
        self.base.validate()
    }

    /// Materialize session `id`'s config: base + per-session seed, mixing
    /// kind and precision (cycled), and name suffix.
    pub fn session_config(&self, id: usize) -> ExperimentConfig {
        let mut cfg = self.base.clone();
        cfg.seed = self.base.seed.wrapping_add((id as u64).wrapping_mul(self.seed_stride));
        if !self.mixing.is_empty() {
            cfg.signal.mixing = self.mixing[id % self.mixing.len()].clone();
        }
        if !self.precision.is_empty() {
            cfg.precision = self.precision[id % self.precision.len()];
        }
        if !self.adapt.is_empty() {
            cfg.adapt.enabled = self.adapt[id % self.adapt.len()];
        }
        cfg.name = format!("{}-{id}", self.base.name);
        cfg
    }

    /// Materialize every session config.
    pub fn session_configs(&self) -> Vec<ExperimentConfig> {
        (0..self.sessions).map(|id| self.session_config(id)).collect()
    }

    /// Materialize session `id`'s lifecycle plan: config plus churn
    /// schedule (arrival threshold from `arrive_stride`, early departure
    /// from the cycled `depart_at` list).
    pub fn session_spec(&self, id: usize) -> SessionSpec {
        let depart_at = if self.depart_at.is_empty() {
            0
        } else {
            self.depart_at[id % self.depart_at.len()]
        };
        SessionSpec {
            cfg: self.session_config(id),
            arrive_at: (id as u64).wrapping_mul(self.arrive_stride),
            depart_at,
        }
    }

    /// Materialize every session's lifecycle plan.
    pub fn session_specs(&self) -> Vec<SessionSpec> {
        (0..self.sessions).map(|id| self.session_spec(id)).collect()
    }

    /// Whether any session arrives late or departs early — i.e. whether
    /// running this scenario exercises the lifecycle churn path.
    pub fn has_churn(&self) -> bool {
        self.arrive_stride > 0 || self.depart_at.iter().any(|&d| d > 0)
    }
}

fn want_str(key: &str, v: &Value) -> Result<String> {
    v.as_str().map(str::to_string).with_context(|| format!("'{key}' must be a string"))
}

fn want_float(key: &str, v: &Value) -> Result<f64> {
    v.as_float().with_context(|| format!("'{key}' must be a number"))
}

fn want_usize(key: &str, v: &Value) -> Result<usize> {
    let i = v.as_int().with_context(|| format!("'{key}' must be an integer"))?;
    if i < 0 {
        bail!("'{key}' must be non-negative, got {i}");
    }
    Ok(i as usize)
}

fn want_bool(key: &str, v: &Value) -> Result<bool> {
    v.as_bool().with_context(|| format!("'{key}' must be a boolean"))
}

/// Accept either a single boolean or a flat array of booleans.
fn want_bool_list(key: &str, v: &Value) -> Result<Vec<bool>> {
    match v {
        Value::Bool(b) => Ok(vec![*b]),
        Value::Array(items) => items
            .iter()
            .map(|it| it.as_bool().with_context(|| format!("'{key}' must contain booleans")))
            .collect(),
        _ => bail!("'{key}' must be a boolean or an array of booleans"),
    }
}

/// Accept either a single non-negative integer or a flat array of them.
fn want_usize_list(key: &str, v: &Value) -> Result<Vec<u64>> {
    let one = |it: &Value| -> Result<u64> {
        let i = it
            .as_int()
            .with_context(|| format!("'{key}' must contain integers"))?;
        if i < 0 {
            bail!("'{key}' entries must be non-negative, got {i}");
        }
        Ok(i as u64)
    };
    match v {
        Value::Array(items) => items.iter().map(one).collect(),
        other => Ok(vec![one(other)?]),
    }
}

/// Accept either a single string or a flat array of strings.
fn want_str_list(key: &str, v: &Value) -> Result<Vec<String>> {
    match v {
        Value::Str(s) => Ok(vec![s.clone()]),
        Value::Array(items) => items
            .iter()
            .map(|it| {
                it.as_str()
                    .map(str::to_string)
                    .with_context(|| format!("'{key}' must contain strings"))
            })
            .collect(),
        _ => bail!("'{key}' must be a string or an array of strings"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn full_round_trip() {
        let doc = r#"
            name = "table1"
            m = 4
            n = 2
            seed = 7
            samples = 50000
            convergence_threshold = 0.05
            engine = "native"

            [optimizer]
            kind = "smbgd"
            mu = 0.004
            gamma = 0.6
            beta = 0.95
            p = 16

            [signal]
            bank = "sub_gaussian"
            mixing = "rotating"
            omega = 2e-4
        "#;
        let cfg = ExperimentConfig::from_toml(doc).unwrap();
        assert_eq!(cfg.name, "table1");
        assert_eq!((cfg.m, cfg.n), (4, 2));
        assert_eq!(cfg.optimizer.kind, OptimizerKind::Smbgd);
        assert_eq!(cfg.optimizer.p, 16);
        assert_eq!(cfg.signal.mixing, "rotating");
        assert_eq!(cfg.signal.omega, 2e-4);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(ExperimentConfig::from_toml("typo_key = 1").is_err());
    }

    #[test]
    fn m_less_than_n_rejected() {
        assert!(ExperimentConfig::from_toml("m = 2\nn = 4").is_err());
    }

    #[test]
    fn bad_optimizer_rejected() {
        let doc = "[optimizer]\nkind = \"adam\"";
        assert!(ExperimentConfig::from_toml(doc).is_err());
    }

    #[test]
    fn bad_mu_rejected() {
        let doc = "[optimizer]\nmu = 1.5";
        assert!(ExperimentConfig::from_toml(doc).is_err());
    }

    #[test]
    fn hub_scenario_round_trip() {
        let doc = r#"
            name = "fleet"
            samples = 9000
            seed = 100

            [optimizer]
            mu = 0.004

            [hub]
            sessions = 6
            shards = 3
            channel_capacity = 1024
            mixing = ["static", "rotating"]
            seed_stride = 10
        "#;
        let sc = HubScenario::from_toml(doc).unwrap();
        assert_eq!((sc.sessions, sc.shards, sc.channel_capacity), (6, 3, 1024));
        assert_eq!(sc.base.samples, 9000);
        let cfgs = sc.session_configs();
        assert_eq!(cfgs.len(), 6);
        assert_eq!(cfgs[0].seed, 100);
        assert_eq!(cfgs[3].seed, 130);
        assert_eq!(cfgs[0].signal.mixing, "static");
        assert_eq!(cfgs[1].signal.mixing, "rotating");
        assert_eq!(cfgs[2].signal.mixing, "static");
        assert_eq!(cfgs[5].name, "fleet-5");
        for c in &cfgs {
            c.validate().unwrap();
        }
    }

    #[test]
    fn hub_scenario_cohort_key() {
        // Cohort stepping defaults on; `hub.cohort = false` opts a
        // scenario back onto the per-session path; non-boolean rejected.
        assert!(HubScenario::default().cohort);
        let sc = HubScenario::from_toml("[hub]\ncohort = false").unwrap();
        assert!(!sc.cohort);
        let sc = HubScenario::from_toml("[hub]\ncohort = true").unwrap();
        assert!(sc.cohort);
        assert!(HubScenario::from_toml("[hub]\ncohort = 1").is_err());
    }

    #[test]
    fn hub_scenario_single_mixing_string() {
        let sc = HubScenario::from_toml("[hub]\nmixing = \"switching\"").unwrap();
        assert_eq!(sc.mixing, vec!["switching".to_string()]);
        assert_eq!(sc.session_config(4).signal.mixing, "switching");
    }

    #[test]
    fn hub_scenario_empty_mixing_inherits_base() {
        let sc = HubScenario::from_toml("[signal]\nmixing = \"rotating\"").unwrap();
        assert_eq!(sc.session_config(2).signal.mixing, "rotating");
    }

    #[test]
    fn hub_scenario_rejects_bad_keys_and_values() {
        assert!(HubScenario::from_toml("[hub]\nsessions = 0").is_err());
        assert!(HubScenario::from_toml("[hub]\nshards = 0").is_err());
        assert!(HubScenario::from_toml("[hub]\nmixing = \"warp\"").is_err());
        assert!(HubScenario::from_toml("[hub]\ntypo = 1").is_err());
        assert!(HubScenario::from_toml("typo = 1").is_err(), "base keys still strict");
    }

    #[test]
    fn hub_scenario_service_keys() {
        let doc = r#"
            [hub]
            listen = "127.0.0.1:0"
            state_dir = "state"

            [hub.autoscale]
            enabled = true
            min_shards = 1
            max_shards = 6
            high = 0.8
            low = 0.05
            sustain = 4
        "#;
        let sc = HubScenario::from_toml(doc).unwrap();
        assert_eq!(sc.listen.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(sc.state_dir.as_deref(), Some("state"));
        assert!(sc.autoscale_enabled);
        assert_eq!((sc.autoscale_min, sc.autoscale_max, sc.autoscale_sustain), (1, 6, 4));
        assert!((sc.autoscale_high - 0.8).abs() < 1e-12);
        assert!((sc.autoscale_low - 0.05).abs() < 1e-12);
        // Defaults leave the service surface off.
        let plain = HubScenario::default();
        assert!(plain.listen.is_none() && plain.state_dir.is_none());
        assert!(!plain.autoscale_enabled);
    }

    #[test]
    fn hub_scenario_fault_keys() {
        let sc = HubScenario::from_toml(
            "[hub]\nstate_dir = \"state\"\nsnapshot_every_ms = 250\nrestart_budget = 5",
        )
        .unwrap();
        assert_eq!(sc.snapshot_every_ms, 250);
        assert_eq!(sc.restart_budget, 5);
        // Defaults: snapshotter off, three respawns per shard slot.
        let plain = HubScenario::default();
        assert_eq!((plain.snapshot_every_ms, plain.restart_budget), (0, 3));
        // A snapshot cadence without a durability root has nowhere to
        // write: rejected at config time.
        let err = HubScenario::from_toml("[hub]\nsnapshot_every_ms = 250")
            .err()
            .expect("cadence without state_dir must fail");
        assert!(format!("{err:#}").contains("state_dir"), "{err:#}");
        // NaN-burst mixing is a legal cycled kind (the chaos drill's
        // poisoned-tenant knob).
        let sc = HubScenario::from_toml("[hub]\nmixing = [\"static\", \"nan_burst\"]").unwrap();
        assert_eq!(sc.session_config(1).signal.mixing, "nan_burst");
        assert!(ExperimentConfig::from_toml("[signal]\nmixing = \"nan_burst\"").is_ok());
    }

    #[test]
    fn hub_scenario_service_keys_validated() {
        assert!(
            HubScenario::from_toml("[hub]\nlisten = \"nocolon\"").is_err(),
            "listen must be host:port"
        );
        assert!(HubScenario::from_toml("[hub]\nstate_dir = \"\"").is_err());
        assert!(HubScenario::from_toml("[hub.autoscale]\nenabled = true\nmin_shards = 0").is_err());
        assert!(
            HubScenario::from_toml(
                "[hub.autoscale]\nenabled = true\nmin_shards = 5\nmax_shards = 2"
            )
            .is_err()
        );
        assert!(
            HubScenario::from_toml("[hub.autoscale]\nenabled = true\nhigh = 0.1\nlow = 0.5")
                .is_err()
        );
        assert!(HubScenario::from_toml("[hub.autoscale]\nenabled = true\nsustain = 0").is_err());
        assert!(
            HubScenario::from_toml("[hub]\nshards = 9\n[hub.autoscale]\nenabled = true").is_err(),
            "initial shards must fit the autoscale envelope"
        );
        // Disabled autoscaler tolerates nonsense knobs (inert).
        assert!(HubScenario::from_toml("[hub.autoscale]\nsustain = 0").is_ok());
        assert!(HubScenario::from_toml("[hub.autoscale]\ntypo = 1").is_err());
        // An empty fleet is only legal for a network server (tenants
        // arrive over the socket).
        assert!(HubScenario::from_toml("[hub]\nsessions = 0").is_err());
        assert!(
            HubScenario::from_toml("[hub]\nsessions = 0\nlisten = \"127.0.0.1:0\"").is_ok()
        );
    }

    #[test]
    fn engine_parse() {
        assert_eq!(EngineKind::parse("pjrt").unwrap(), EngineKind::Pjrt);
        assert!(EngineKind::parse("gpu").is_err());
    }

    #[test]
    fn precision_parse_round_trip() {
        for p in [Precision::F32, Precision::F64, Precision::Q16, Precision::Q32] {
            assert_eq!(Precision::parse(p.name()).unwrap(), p);
        }
        assert!(Precision::parse("f16").is_err());
        assert!(Precision::parse("q8").is_err());
    }

    #[test]
    fn precision_config_key() {
        let cfg = ExperimentConfig::from_toml("precision = \"f32\"").unwrap();
        assert_eq!(cfg.precision, Precision::F32);
        let cfg = ExperimentConfig::from_toml("precision = \"q16\"").unwrap();
        assert_eq!(cfg.precision, Precision::Q16);
        assert_eq!(ExperimentConfig::default().precision, Precision::F64);
        assert!(ExperimentConfig::from_toml("precision = \"f16\"").is_err());
    }

    #[test]
    fn f32_requires_native_engine() {
        let doc = "engine = \"pjrt\"\nprecision = \"f32\"";
        assert!(ExperimentConfig::from_toml(doc).is_err());
        let doc = "engine = \"native\"\nprecision = \"f32\"";
        assert!(ExperimentConfig::from_toml(doc).is_ok());
        // Fixed point is a native-only datapath too.
        let doc = "engine = \"pjrt\"\nprecision = \"q16\"";
        assert!(ExperimentConfig::from_toml(doc).is_err());
        let doc = "engine = \"native\"\nprecision = \"q32\"";
        assert!(ExperimentConfig::from_toml(doc).is_ok());
    }

    #[test]
    fn adapt_config_keys_round_trip() {
        let doc = r#"
            [adapt]
            enabled = true
            stride = 2
            alpha = 0.05
            boost = 3.0
            tau = 2000
            floor_c = 0.002
            floor_min = 0.0005
            rollback = false
        "#;
        let cfg = ExperimentConfig::from_toml(doc).unwrap();
        assert!(cfg.adapt.enabled);
        assert_eq!(cfg.adapt.stride, 2);
        assert_eq!(cfg.adapt.alpha, 0.05);
        assert_eq!(cfg.adapt.boost, 3.0);
        assert_eq!(cfg.adapt.tau, 2000.0);
        assert_eq!(cfg.adapt.floor_c, 0.002);
        assert_eq!(cfg.adapt.floor_min, 0.0005);
        assert!(!cfg.adapt.rollback);
        // Defaults: disabled, valid.
        let d = ExperimentConfig::default();
        assert!(!d.adapt.enabled);
        d.adapt.validate().unwrap();
    }

    #[test]
    fn adapt_config_rejects_nonsense() {
        assert!(ExperimentConfig::from_toml("[adapt]\nstride = 0").is_err());
        assert!(ExperimentConfig::from_toml("[adapt]\nboost = 0.5").is_err());
        assert!(ExperimentConfig::from_toml("[adapt]\nalpha = 2.0").is_err());
        assert!(ExperimentConfig::from_toml("[adapt]\narmed_level = 0.9").is_err());
        assert!(ExperimentConfig::from_toml("[adapt]\nenabled = \"yes\"").is_err());
        assert!(ExperimentConfig::from_toml("[adapt]\ntypo = 1").is_err());
    }

    #[test]
    fn adapt_schedule_mapping() {
        let cfg = AdaptConfig::default();
        let s = cfg.schedule(0.01);
        s.validate();
        assert!(matches!(
            s,
            crate::ica::MuSchedule::Adaptive { mu0, boost, .. }
                if mu0 == 0.01 && boost == cfg.boost
        ));
        // Micro-μ configs stay valid: the floor caps at μ₀ like the
        // governor's.
        cfg.schedule(1e-4).validate();
    }

    #[test]
    fn drift_mixing_kinds_accepted() {
        let doc = "[signal]\nmixing = \"switch_once\"\nswitch_at = 12000";
        let cfg = ExperimentConfig::from_toml(doc).unwrap();
        assert_eq!(cfg.signal.mixing, "switch_once");
        assert_eq!(cfg.signal.switch_at, 12_000);
        assert!(ExperimentConfig::from_toml("[signal]\nmixing = \"drift_onset\"").is_ok());
        assert_eq!(ExperimentConfig::default().signal.switch_at, 50_000);
    }

    #[test]
    fn hub_scenario_cycles_adapt() {
        let sc = HubScenario::from_toml("[hub]\nadapt = [true, false]").unwrap();
        assert!(sc.session_config(0).adapt.enabled);
        assert!(!sc.session_config(1).adapt.enabled);
        assert!(sc.session_config(2).adapt.enabled);
        // Single boolean form and inheritance.
        let sc = HubScenario::from_toml("[hub]\nadapt = true").unwrap();
        assert!(sc.session_config(3).adapt.enabled);
        let sc = HubScenario::from_toml("[adapt]\nenabled = true").unwrap();
        assert!(sc.session_config(2).adapt.enabled);
        assert!(HubScenario::from_toml("[hub]\nadapt = [1, 0]").is_err());
    }

    #[test]
    fn hub_scenario_parses_lifecycle_keys() {
        let doc = r#"
            samples = 9000

            [hub]
            sessions = 4
            shards = 2
            placement = "modulo"
            arrive_stride = 2500
            depart_at = [0, 4000]
        "#;
        let sc = HubScenario::from_toml(doc).unwrap();
        assert_eq!(sc.placement, PlacementKind::Modulo);
        assert!(sc.has_churn());
        let specs = sc.session_specs();
        assert_eq!(specs.len(), 4);
        assert_eq!(specs[0].arrive_at, 0);
        assert_eq!(specs[3].arrive_at, 7500);
        assert_eq!(specs[0].depart_at, 0);
        assert_eq!(specs[1].depart_at, 4000);
        assert_eq!(specs[0].effective_samples(), 9000);
        assert_eq!(specs[1].effective_samples(), 4000);
        // depart_at beyond the stream length is a full run.
        let mut long = sc.clone();
        long.depart_at = vec![20_000];
        assert_eq!(long.session_spec(0).effective_samples(), 9000);
        // Defaults: least-loaded, no churn.
        let d = HubScenario::default();
        assert_eq!(d.placement, PlacementKind::LeastLoaded);
        assert!(!d.has_churn());
        assert_eq!(d.session_spec(5).arrive_at, 0);
        // Rejects.
        assert!(HubScenario::from_toml("[hub]\nplacement = \"hash\"").is_err());
        assert!(HubScenario::from_toml("[hub]\ndepart_at = [-1]").is_err());
        assert!(HubScenario::from_toml("[hub]\ndepart_at = [\"x\"]").is_err());
    }

    #[test]
    fn placement_parse_round_trip() {
        for p in
            [PlacementKind::LeastLoaded, PlacementKind::Modulo, PlacementKind::CohortAffinity]
        {
            assert_eq!(PlacementKind::parse(p.name()).unwrap(), p);
        }
        assert!(PlacementKind::parse("random").is_err());
    }

    #[test]
    fn hub_scenario_cycles_precisions() {
        let sc = HubScenario::from_toml("[hub]\nprecision = [\"f32\", \"f64\"]").unwrap();
        assert_eq!(sc.session_config(0).precision, Precision::F32);
        assert_eq!(sc.session_config(1).precision, Precision::F64);
        assert_eq!(sc.session_config(4).precision, Precision::F32);
        // Fixed-point tenants cycle beside floats in one hub.
        let sc =
            HubScenario::from_toml("[hub]\nprecision = [\"q16\", \"f32\", \"f64\"]").unwrap();
        assert_eq!(sc.session_config(0).precision, Precision::Q16);
        assert_eq!(sc.session_config(3).precision, Precision::Q16);
        assert_eq!(sc.session_config(5).precision, Precision::F64);
        // Cycled q16 with a pjrt base engine is rejected like f32.
        let doc = "engine = \"pjrt\"\n[hub]\nprecision = [\"q16\"]";
        assert!(HubScenario::from_toml(doc).is_err());
        // Single string form and inheritance.
        let sc = HubScenario::from_toml("[hub]\nprecision = \"f32\"").unwrap();
        assert_eq!(sc.session_config(3).precision, Precision::F32);
        let sc = HubScenario::from_toml("precision = \"f32\"").unwrap();
        assert_eq!(sc.session_config(2).precision, Precision::F32);
        assert!(HubScenario::from_toml("[hub]\nprecision = \"f16\"").is_err());
        // Cycled f32 with a pjrt base engine is rejected at config time,
        // matching the non-cycled check in ExperimentConfig::validate.
        let doc = "engine = \"pjrt\"\n[hub]\nprecision = [\"f32\", \"f64\"]";
        assert!(HubScenario::from_toml(doc).is_err());
    }
}
