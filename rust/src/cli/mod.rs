//! Minimal CLI argument parser (stand-in for `clap`, unavailable offline).
//!
//! Grammar: `easi-ica <command> [--flag value]... [--switch]...`.
//! Unknown flags are errors; every command documents its flags in
//! [`usage`].

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed command line: a command name plus `--key value` flags.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

/// Flags that take no value. ("normalized" used to sit here unconsumed —
/// EasiSgd's normalized mode is a library-level knob no command exposes;
/// listing it only made `--normalized` parse and then fail validation.)
const SWITCHES: &[&str] = &["help", "verbose", "quick", "restore-latest"];

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut it = raw.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        if command.starts_with("--") {
            bail!("expected a command before flags; see `easi-ica help`");
        }
        let mut args = Args { command, ..Default::default() };
        while let Some(tok) = it.next() {
            let Some(name) = tok.strip_prefix("--") else {
                bail!("unexpected positional argument '{tok}'");
            };
            if name.is_empty() {
                bail!("empty flag name");
            }
            if SWITCHES.contains(&name) {
                args.switches.push(name.to_string());
            } else {
                let value = it
                    .next()
                    .with_context(|| format!("flag --{name} requires a value"))?;
                if args.flags.insert(name.to_string(), value).is_some() {
                    bail!("duplicate flag --{name}");
                }
            }
        }
        Ok(args)
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} must be an integer")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} must be an integer")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} must be a number")),
        }
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Error if any flag or switch not in `allowed` was supplied (catches
    /// typos, and switches that a command does not actually consume —
    /// accepting `--quick` on a command that ignores it would break the
    /// "unknown flags are errors" contract). `--help` and `--verbose`
    /// are accepted everywhere.
    pub fn expect_only(&self, allowed: &[&str]) -> Result<()> {
        const GLOBAL_SWITCHES: &[&str] = &["help", "verbose"];
        for k in self.flags.keys() {
            if !allowed.contains(&k.as_str()) {
                bail!(
                    "unknown flag --{k} for command '{}' (allowed: {})",
                    self.command,
                    allowed.join(", ")
                );
            }
        }
        for s in &self.switches {
            if !allowed.contains(&s.as_str()) && !GLOBAL_SWITCHES.contains(&s.as_str()) {
                bail!(
                    "switch --{s} is not accepted by command '{}' (allowed: {})",
                    self.command,
                    allowed.join(", ")
                );
            }
        }
        Ok(())
    }
}

/// Top-level usage text.
pub fn usage() -> &'static str {
    "easi-ica — adaptive ICA via EASI with SMBGD (paper reproduction)\n\
     \n\
     USAGE: easi-ica <command> [flags]\n\
     \n\
     COMMANDS\n\
       run            stream an experiment through the coordinator\n\
                      --config FILE | [--m N --n N --optimizer sgd|smbgd|mbgd\n\
                      --engine native|pjrt --precision f32|f64|q16|q32 --samples N\n\
                      --mu F --gamma F --beta F --p N --adapt on|off\n\
                      --mixing static|rotating|switching|switch_once|drift_onset\n\
                      --switch-at N --seed N]\n\
       serve-many     elastic serving plane: N concurrent sessions admitted\n\
                      onto a worker-shard pool (least-loaded, modulo or\n\
                      cohort-affinity placement), with per-shard\n\
                      backpressure, optional\n\
                      session churn, a live per-tenant health table, and an\n\
                      aggregate throughput table\n\
                      [--listen HOST:PORT (serve the hub command plane over\n\
                       framed TCP — attach/detach/pause/resume/checkpoint/\n\
                       restore/infer — until a client sends SHUTDOWN;\n\
                       prints `LISTENING <addr>` once bound, --sessions 0\n\
                       starts an empty fleet)\n\
                       --state-dir DIR (durability root: detach-to-disk\n\
                       snapshots land here and restore bit-identically\n\
                       after a restart)\n\
                       --autoscale-max N (enable queue-pressure shard\n\
                       autoscaling, growing/shrinking the worker pool\n\
                       within [min, N]; decisions appear in the status\n\
                       table's press column and footer)\n\
                       --snapshot-every MS (crash-consistent background\n\
                       snapshots of every live tenant into --state-dir on\n\
                       this cadence, without parking anyone; 0 = off)\n\
                       --restore-latest (on startup, resume every snapshot\n\
                       found in --state-dir — a SIGKILLed server comes\n\
                       back with its fleet; torn *.tmp leftovers and\n\
                       quarantine parks are reported and skipped)\n\
                       --restart-budget N (supervisor respawns granted to\n\
                       each shard slot before it is declared failed)]\n\
                      [--config FILE | --sessions N --shards N --samples N\n\
                       --mixing a,b,c --precision f32,f64,q16,q32 --adapt\n\
                       on,off (both cycled per session; q16/q32 tenants\n\
                       run the fixed-point Q-format datapath with\n\
                       saturation-latch divergence guards — see the\n\
                       status table's sat column)\n\
                       (cycled per session) --capacity N --seed N\n\
                       --seed-stride N --switch-at N\n\
                       --placement least_loaded|modulo|cohort_affinity\n\
                       --cohort on|off (tenant-major cohort stepping of\n\
                       same-shape sessions; on by default, bit-identical\n\
                       to the per-session path)\n\
                       --churn S[,D] (stagger arrivals by S aggregate\n\
                       samples; with D every other tenant departs after D\n\
                       of its own samples)\n\
                       --status-every MS (print the live StateDirectory\n\
                       health table every MS milliseconds)\n\
                       --mu F --gamma F --beta F --p N --m N --n N\n\
                       --optimizer sgd|smbgd|mbgd --engine native|pjrt\n\
                       --artifacts DIR]\n\
       convergence    E1 (paper SSV.A): SGD vs SMBGD iterations-to-convergence\n\
                      [--runs N --m N --n N --mu F --gamma F --beta F --p N]\n\
       table1         E2 (paper Table I): FPGA model, both architectures\n\
                      [--m N --n N --g cube|tanh|signed_square\n\
                       --format float|fixed16|fixed32]\n\
       depth-sweep    E3: (m,n) sweep of depth/Fmax/MIPS/resources\n\
       ablation       A1/A2: --what hyper|nonlinearity [--runs N]\n\
       tracking       A3: adaptive tracking vs frozen FastICA\n\
                      [--omega F --samples N]\n\
       track          adaptive control plane drift study: detection latency\n\
                      and re-convergence of the closed loop (adapt subsystem)\n\
                      vs the best fixed DecayToFloor schedules under one\n\
                      abrupt mixing switch\n\
                      [--samples N --switch-at N --m N --n N --seed N\n\
                       --mu F --tau F --threshold F]\n\
       dump-datapath  E4 (Figs. 1-2): print the datapath block structure\n\
                      [--m N --n N --arch sgd|smbgd]\n\
       fpga-report    machine-readable resource/timing/accuracy artifact\n\
                      (schema easi-ica-fpga-report/v1): Table-I model\n\
                      numbers for float32/fixed16/fixed32, Q-format\n\
                      calibration from an observed dynamic range, and\n\
                      q16/q32 Amari accuracy vs the f64 reference\n\
                      [--m N --n N --g cube|tanh|signed_square --out PATH]\n\
       separate       run FastICA on a synthetic dataset and report metrics\n\
                      [--m N --n N --samples N --seed N]\n\
       bench          §Perf hot-path suite (f64 + f32 + adapt + cohort\n\
                      kernels) → BENCH_hotpath.json (repo root)\n\
                      [--quick --out PATH --check BASELINE.json\n\
                       --tolerance F --min-fused-speedup F --min-f32-speedup F\n\
                       --min-cohort-speedup F --max-adapt-overhead F\n\
                       --max-status-overhead F --max-snapshot-overhead F\n\
                       --max-qfx-overhead F | --promote ARTIFACT.json]\n\
                      with --check, exits nonzero if any gated kernel's\n\
                      machine-normalized cost regressed past the tolerance;\n\
                      --promote installs a measured artifact as the\n\
                      committed BENCH_baseline.json (validates kernel-family\n\
                      coverage, drops build-specific *_simd records, flips\n\
                      mode to \"measured\")\n\
       help           this text\n"
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Args> {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn parses_command_and_flags() {
        let a = parse("table1 --m 4 --n 2").unwrap();
        assert_eq!(a.command, "table1");
        assert_eq!(a.get_usize("m", 0).unwrap(), 4);
        assert_eq!(a.get_usize("n", 0).unwrap(), 2);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run").unwrap();
        assert_eq!(a.get_usize("samples", 1000).unwrap(), 1000);
        assert_eq!(a.get_f64("mu", 0.01).unwrap(), 0.01);
        assert_eq!(a.get_str("engine", "native"), "native");
    }

    #[test]
    fn switches_take_no_value() {
        let a = parse("run --verbose --m 4").unwrap();
        assert!(a.switch("verbose"));
        assert_eq!(a.get_usize("m", 0).unwrap(), 4);
    }

    #[test]
    fn bench_flags_parse() {
        let a = parse("bench --quick --check BENCH_baseline.json --tolerance 0.3").unwrap();
        assert_eq!(a.command, "bench");
        assert!(a.switch("quick"));
        assert_eq!(a.get("check"), Some("BENCH_baseline.json"));
        assert_eq!(a.get_f64("tolerance", 0.0).unwrap(), 0.3);
        let allowed =
            ["quick", "check", "tolerance", "out", "min-fused-speedup", "min-f32-speedup"];
        assert!(a.expect_only(&allowed).is_ok());
    }

    #[test]
    fn unconsumed_switch_rejected() {
        // A switch the command does not consume is an error, not a no-op…
        let a = parse("table1 --quick").unwrap();
        assert!(a.expect_only(&["m", "n"]).is_err());
        // …while the global switches stay accepted everywhere.
        let a = parse("table1 --verbose").unwrap();
        assert!(a.expect_only(&["m", "n"]).is_ok());
    }

    #[test]
    fn restore_latest_is_a_switch() {
        let a = parse("serve-many --restore-latest --state-dir state").unwrap();
        assert!(a.switch("restore-latest"));
        assert_eq!(a.get("state-dir"), Some("state"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse("run --m").is_err());
    }

    #[test]
    fn duplicate_flag_errors() {
        assert!(parse("run --m 4 --m 8").is_err());
    }

    #[test]
    fn expect_only_catches_typos() {
        let a = parse("table1 --mm 4").unwrap();
        assert!(a.expect_only(&["m", "n"]).is_err());
    }

    #[test]
    fn positional_rejected() {
        assert!(parse("run stray").is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("run --m four").unwrap();
        assert!(a.get_usize("m", 0).is_err());
    }
}
