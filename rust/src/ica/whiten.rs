//! Whitening (sphering) — the preprocessing substrate FastICA needs.
//!
//! EASI famously *merges* whitening into the separation update (§III);
//! FastICA does not, so the nonadaptive baseline needs an explicit
//! whitening stage: `z = W x` with `W = D^{−1/2} Eᵀ` from the
//! eigendecomposition `Cov(x) = E D Eᵀ`, optionally reducing to the top-n
//! eigendirections (m → n dimensionality reduction).

use crate::linalg::{jacobi_eig, Mat64};
use anyhow::{bail, Result};

/// Whitening transform fitted on a batch of observations.
pub struct Whitener {
    /// `n × m` whitening matrix.
    pub w: Mat64,
    /// Per-channel means subtracted before projecting.
    pub mean: Vec<f64>,
    /// Retained eigenvalues (descending), for diagnostics.
    pub eigenvalues: Vec<f64>,
}

impl Whitener {
    /// Fit on `x` (T × m), retaining `n ≤ m` components.
    pub fn fit(x: &Mat64, n: usize) -> Result<Self> {
        let (t, m) = x.shape();
        if n == 0 || n > m {
            bail!("whiten: need 1 <= n <= m, got n={n}, m={m}");
        }
        if t < 2 * m {
            bail!("whiten: too few samples ({t}) for {m} channels");
        }

        // Channel means.
        let mut mean = vec![0.0; m];
        for i in 0..t {
            for (j, mu) in mean.iter_mut().enumerate() {
                *mu += x[(i, j)];
            }
        }
        mean.iter_mut().for_each(|v| *v /= t as f64);

        // Covariance (m × m).
        let mut cov = Mat64::zeros(m, m);
        for i in 0..t {
            for a in 0..m {
                let xa = x[(i, a)] - mean[a];
                for b in a..m {
                    let xb = x[(i, b)] - mean[b];
                    cov[(a, b)] += xa * xb;
                }
            }
        }
        for a in 0..m {
            for b in a..m {
                let v = cov[(a, b)] / (t as f64 - 1.0);
                cov[(a, b)] = v;
                cov[(b, a)] = v;
            }
        }

        let eig = jacobi_eig(&cov)?;
        // Guard: retained spectrum must be positive.
        for &ev in eig.values.iter().take(n) {
            if ev <= 1e-12 {
                bail!("whiten: covariance nearly singular (eigenvalue {ev})");
            }
        }
        // W = D^{-1/2} Eᵀ restricted to the top n eigenpairs.
        let w = Mat64::from_fn(n, m, |i, j| eig.vectors[(j, i)] / eig.values[i].sqrt());
        Ok(Self { w, mean, eigenvalues: eig.values[..n].to_vec() })
    }

    /// Apply to a batch: returns `z` (T × n) with identity covariance.
    pub fn transform(&self, x: &Mat64) -> Mat64 {
        let (t, m) = x.shape();
        assert_eq!(m, self.mean.len(), "whiten transform: channel mismatch");
        let n = self.w.rows();
        let mut z = Mat64::zeros(t, n);
        let mut centered = vec![0.0; m];
        for i in 0..t {
            for (j, c) in centered.iter_mut().enumerate() {
                *c = x[(i, j)] - self.mean[j];
            }
            let zi = self.w.matvec(&centered);
            z.row_mut(i).copy_from_slice(&zi);
        }
        z
    }
}

/// Empirical covariance of `x` (T × m) — shared test helper.
pub fn covariance(x: &Mat64) -> Mat64 {
    let (t, m) = x.shape();
    let mut mean = vec![0.0; m];
    for i in 0..t {
        for (j, mu) in mean.iter_mut().enumerate() {
            *mu += x[(i, j)];
        }
    }
    mean.iter_mut().for_each(|v| *v /= t as f64);
    let mut cov = Mat64::zeros(m, m);
    for i in 0..t {
        for a in 0..m {
            for b in 0..m {
                cov[(a, b)] += (x[(i, a)] - mean[a]) * (x[(i, b)] - mean[b]);
            }
        }
    }
    cov.scale(1.0 / (t as f64 - 1.0));
    cov
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::signal::Dataset;

    #[test]
    fn whitened_covariance_is_identity() {
        let ds = Dataset::standard(1, 4, 2, 20_000);
        let wh = Whitener::fit(&ds.x, 2).unwrap();
        let z = wh.transform(&ds.x);
        let cov = covariance(&z);
        assert!(
            cov.max_abs_diff(&Mat64::eye(2, 2)) < 0.05,
            "cov(z) != I: {cov:?}"
        );
    }

    #[test]
    fn full_rank_whitening() {
        let ds = Dataset::standard(2, 4, 4, 20_000);
        let wh = Whitener::fit(&ds.x, 4).unwrap();
        let z = wh.transform(&ds.x);
        let cov = covariance(&z);
        assert!(cov.max_abs_diff(&Mat64::eye(4, 4)) < 0.08);
    }

    #[test]
    fn eigenvalues_descending_positive() {
        let ds = Dataset::standard(3, 4, 2, 10_000);
        let wh = Whitener::fit(&ds.x, 2).unwrap();
        assert!(wh.eigenvalues[0] >= wh.eigenvalues[1]);
        assert!(wh.eigenvalues[1] > 0.0);
    }

    #[test]
    fn rejects_bad_n() {
        let ds = Dataset::standard(4, 4, 2, 1000);
        assert!(Whitener::fit(&ds.x, 0).is_err());
        assert!(Whitener::fit(&ds.x, 5).is_err());
    }

    #[test]
    fn rejects_too_few_samples() {
        let ds = Dataset::standard(5, 4, 2, 6);
        assert!(Whitener::fit(&ds.x, 2).is_err());
    }

    #[test]
    fn mean_is_removed() {
        let ds = Dataset::standard(6, 4, 2, 20_000);
        // Shift channel 0 by +10
        let mut x = ds.x.clone();
        for i in 0..x.rows() {
            x[(i, 0)] += 10.0;
        }
        let wh = Whitener::fit(&x, 2).unwrap();
        let z = wh.transform(&x);
        // Column means of z ~ 0
        for j in 0..2 {
            let mut mu = 0.0;
            for i in 0..z.rows() {
                mu += z[(i, j)];
            }
            mu /= z.rows() as f64;
            assert!(mu.abs() < 0.05, "z mean {mu}");
        }
    }
}
