//! Fixed-point arithmetic simulation — the numeric substrate of the
//! *prior* FPGA implementations the paper compares against.
//!
//! Odom [12] implements EASI with 16-bit fixed-point variables; the paper
//! argues for 32-bit floating point ("a fair comparison of our work with
//! previous work is hard because our work uses 32-bit floating point...").
//! This module makes that argument testable: [`QFormat`] models signed
//! fixed-point with rounding + saturation, and [`QuantizedEasi`] runs the
//! EASI SGD update with *every* intermediate quantized, simulating the
//! fixed-point datapath bit-growth behaviour. The A4 ablation
//! (`cargo bench --bench ablation_quant`) sweeps word length and shows
//! where separation quality falls off a cliff.
//!
//! Since the `qfx` datapath landed, this module is a thin veneer over it:
//! [`QFormat::quantize`] delegates to [`quantize_rne`](crate::qfx::quantize_rne) (one rounding
//! routine — RNE, two's-complement saturation — shared with the servable
//! [`Fixed`](crate::qfx::Fixed) scalars), and [`QuantizedEasi`] routes exact-lattice
//! formats (Q3.12, Q2.14, Q7.24, Q4.28) through the same fused
//! fixed-point kernels the serving plane's `q16`/`q32` tenants run.
//! Arbitrary word lengths (the A4 sweep's 8-bit cliff) fall back to the
//! legacy requantize-every-stage f64 model.

use super::nonlinearity::{with_g, Nonlinearity};
use super::Optimizer;
use crate::linalg::{fused, FusedScratch, Mat, Mat64};
use crate::qfx::{quantize_rne, Fixed};

/// Signed fixed-point format Q`int_bits`.`frac_bits` (plus sign bit).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QFormat {
    /// Integer bits (excluding sign).
    pub int_bits: u32,
    /// Fractional bits.
    pub frac_bits: u32,
}

impl QFormat {
    /// Common shorthand: total word length with `int_bits` integer bits.
    /// `QFormat::new(3, 12)` is a 16-bit word (1 sign + 3 int + 12 frac).
    pub const fn new(int_bits: u32, frac_bits: u32) -> Self {
        Self { int_bits, frac_bits }
    }

    /// The 16-bit format of Odom [12]-style implementations (Q3.12).
    pub const fn q16() -> Self {
        Self::new(3, 12)
    }

    /// A 32-bit fixed-point format (Q7.24).
    pub const fn q32() -> Self {
        Self::new(7, 24)
    }

    /// Total word length including the sign bit.
    pub fn word_bits(&self) -> u32 {
        1 + self.int_bits + self.frac_bits
    }

    /// Largest representable value (`(2^(int+frac) − 1) · 2^-frac`).
    pub fn max_value(&self) -> f64 {
        self.max_raw() as f64 / (1u64 << self.frac_bits) as f64
    }

    /// Most negative representable value (`−2^(int+frac) · 2^-frac`): the
    /// two's-complement rail, one LSB beyond `−max_value()`.
    pub fn min_value(&self) -> f64 {
        self.min_raw() as f64 / (1u64 << self.frac_bits) as f64
    }

    /// Resolution (value of one LSB).
    pub fn lsb(&self) -> f64 {
        1.0 / (1u64 << self.frac_bits) as f64
    }

    fn max_raw(&self) -> i64 {
        (1i64 << (self.int_bits + self.frac_bits)) - 1
    }

    fn min_raw(&self) -> i64 {
        -(1i64 << (self.int_bits + self.frac_bits))
    }

    /// Quantize: round to nearest (ties to even) at `frac_bits`, saturate
    /// to the two's-complement rails — never wraparound, the standard DSP
    /// datapath choice. Delegates to [`quantize_rne`](crate::qfx::quantize_rne), so every
    /// `QFormat` shares the exact rounding semantics of the servable
    /// [`Fixed`](crate::qfx::Fixed) scalars; `QFormat::q16()` *is* the `Fixed::<12>`
    /// lattice (pinned by this module's regression tests).
    pub fn quantize(&self, v: f64) -> f64 {
        quantize_rne(v, self.frac_bits, self.min_raw(), self.max_raw())
    }

    /// Quantize a slice in place.
    pub fn quantize_slice(&self, xs: &mut [f64]) {
        xs.iter_mut().for_each(|v| *v = self.quantize(*v));
    }

    /// Quantize a matrix in place.
    pub fn quantize_mat(&self, m: &mut Mat64) {
        self.quantize_slice(m.as_mut_slice());
    }
}

/// EASI SGD with a fully-quantized datapath: inputs, `y`, `g(y)`, every
/// `H` entry, the `μHB` product and the stored `B` all live in `fmt`.
///
/// Two execution paths, selected by the format:
///
/// - **Exact-lattice formats** (Q3.12, Q2.14, Q7.24, Q4.28 — the four
///   word layouts [`Fixed`](crate::qfx::Fixed) can represent) run the
///   fused fixed-point kernels the serving plane's `q16`/`q32` tenants
///   run: every product individually RNE-rounded, adds exact integer
///   adds, rails saturating. This is bit-for-bit the hardware model
///   (`fpga::exec` pins it against the datapath graphs).
/// - **Arbitrary word lengths** (the A4 sweep's 8-bit cliff, formats
///   with no `Fixed` instantiation) fall back to the legacy model:
///   compute each stage in f64, requantize its output. Looser than real
///   hardware (accumulates never round), but defined for any width.
///
/// `B` is held as `Mat64` on the format's lattice; since every lattice
/// value is a dyadic rational exactly representable in f64, the per-step
/// casts on the exact-lattice path are lossless round trips.
pub struct QuantizedEasi {
    b: Mat64,
    mu: f64,
    g: Nonlinearity,
    fmt: QFormat,
    samples: u64,
    // Scratch
    y: Vec<f64>,
    gy: Vec<f64>,
    h: Mat64,
    hb: Mat64,
    xq: Vec<f64>,
}

impl QuantizedEasi {
    pub fn new(mut b0: Mat64, mu: f64, g: Nonlinearity, fmt: QFormat) -> Self {
        assert!(mu > 0.0);
        fmt.quantize_mat(&mut b0);
        let (n, m) = b0.shape();
        Self {
            mu: fmt.quantize(mu).max(fmt.lsb()), // μ below 1 LSB freezes learning
            g,
            fmt,
            samples: 0,
            y: vec![0.0; n],
            gy: vec![0.0; n],
            h: Mat64::zeros(n, n),
            hb: Mat64::zeros(n, m),
            xq: vec![0.0; m],
            b: b0,
        }
    }

    pub fn with_identity_init(n: usize, m: usize, mu: f64, g: Nonlinearity, fmt: QFormat) -> Self {
        let mut b0 = Mat64::eye(n, m);
        b0.scale(0.5);
        Self::new(b0, mu, g, fmt)
    }

    pub fn format(&self) -> QFormat {
        self.fmt
    }

    /// The effective learning rate after quantization.
    pub fn effective_mu(&self) -> f64 {
        self.mu
    }

    /// The [`Fixed`](crate::qfx::Fixed) fraction width whose lattice
    /// (word length *and* rails) matches `fmt` exactly, if any.
    fn fixed_frac(fmt: QFormat) -> Option<u32> {
        match (fmt.int_bits, fmt.frac_bits) {
            (3, 12) => Some(12),  // legacy QFormat::q16() (Q3.12)
            (1, 14) => Some(14),  // serving q16 (Q2.14)
            (7, 24) => Some(24),  // legacy QFormat::q32() (Q7.24)
            (3, 28) => Some(28),  // serving q32 (Q4.28)
            _ => None,
        }
    }

    /// Whether steps run through the `qfx` fused fixed-point kernels
    /// (exact-lattice formats) or the requantize-every-stage fallback.
    pub fn uses_qfx_kernels(&self) -> bool {
        Self::fixed_frac(self.fmt).is_some()
    }

    /// One sample through the fused fixed-point kernels — the identical
    /// code path `q16`/`q32` tenants serve on. The casts in and out are
    /// lossless (`B` lives on the lattice); the small per-step scratch
    /// allocation is fine for this simulation/ablation path.
    fn qfx_step<const F: u32>(&mut self, x: &[f64]) {
        let (n, m) = self.b.shape();
        let mut bq: Mat<Fixed<F>> = self.b.cast();
        let xq: Vec<Fixed<F>> = x.iter().map(|&v| Fixed::<F>::from_f64(v)).collect();
        let mut s = FusedScratch::<Fixed<F>>::new(n, m);
        let mu = Fixed::<F>::from_f64(self.mu);
        with_g!(Fixed<F>, self.g, gf => {
            fused::relative_gradient_step_into(&mut bq, &xq, gf, mu, &mut s);
        });
        self.b = bq.cast();
    }

    /// One sample through the legacy model: every stage computed in f64,
    /// its output requantized onto the format's lattice.
    fn requantized_step(&mut self, x: &[f64]) {
        let fmt = self.fmt;
        // Input quantization (ADC).
        self.xq.copy_from_slice(x);
        fmt.quantize_slice(&mut self.xq);

        // y = Bx, quantized after the accumulate.
        self.b.matvec_into(&self.xq, &mut self.y);
        fmt.quantize_slice(&mut self.y);

        // g(y), quantized.
        self.g.apply_slice(&self.y, &mut self.gy);
        fmt.quantize_slice(&mut self.gy);

        // H = yyᵀ − I + gyᵀ − ygᵀ, every entry quantized.
        let n = self.y.len();
        for i in 0..n {
            for j in 0..n {
                let mut v =
                    self.y[i] * self.y[j] + self.gy[i] * self.y[j] - self.y[i] * self.gy[j];
                if i == j {
                    v -= 1.0;
                }
                self.h[(i, j)] = fmt.quantize(v);
            }
        }

        // B ← B − μ(HB), products and the update quantized.
        self.h.matmul_into(&self.b, &mut self.hb);
        for (b, u) in self.b.as_mut_slice().iter_mut().zip(self.hb.as_slice()) {
            *b = fmt.quantize(*b - fmt.quantize(self.mu * *u));
        }
    }
}

impl Optimizer for QuantizedEasi {
    fn step(&mut self, x: &[f64]) {
        match Self::fixed_frac(self.fmt) {
            Some(12) => self.qfx_step::<12>(x),
            Some(14) => self.qfx_step::<14>(x),
            Some(24) => self.qfx_step::<24>(x),
            Some(28) => self.qfx_step::<28>(x),
            _ => self.requantized_step(x),
        }
        self.samples += 1;
    }

    fn b(&self) -> &Mat64 {
        &self.b
    }

    fn b_mut(&mut self) -> &mut Mat64 {
        &mut self.b
    }

    fn samples_seen(&self) -> u64 {
        self.samples
    }

    fn name(&self) -> &'static str {
        "easi-sgd-fixed"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ica::{amari_index, EasiSgd};
    use crate::signal::Dataset;

    #[test]
    fn quantize_rounds_to_lsb() {
        let fmt = QFormat::new(3, 4); // LSB = 1/16
        assert_eq!(fmt.quantize(0.06), 0.0625);
        assert_eq!(fmt.quantize(0.03), 0.0); // below LSB/2: rounds to zero
        assert_eq!(fmt.quantize(-0.06), -0.0625);
    }

    #[test]
    fn quantize_saturates() {
        // Two's-complement rails: the negative rail sits one LSB beyond
        // the positive one (−4.0 vs 3.9375), exactly like `qfx::Fixed`.
        let fmt = QFormat::new(2, 4);
        assert_eq!(fmt.max_value(), 3.9375);
        assert_eq!(fmt.min_value(), -4.0);
        assert_eq!(fmt.quantize(100.0), fmt.max_value());
        assert_eq!(fmt.quantize(-100.0), fmt.min_value());
    }

    #[test]
    fn quantize_rounds_ties_to_even() {
        let fmt = QFormat::new(3, 4); // LSB = 1/16
        // 1.5·lsb and 2.5·lsb both land on the even neighbour (2·lsb).
        assert_eq!(fmt.quantize(0.09375), 0.125);
        assert_eq!(fmt.quantize(0.15625), 0.125);
        assert_eq!(fmt.quantize(-0.15625), -0.125);
        // A 0.5·lsb tie goes down to zero (even), not away from it.
        assert_eq!(fmt.quantize(0.03125), 0.0);
        assert_eq!(fmt.quantize(-0.03125), 0.0);
    }

    #[test]
    fn quantize_matches_fixed_lattice_exactly() {
        // The satellite regression pin: QFormat::quantize is the same
        // function as Fixed::from_f64 on every format Fixed instantiates.
        // Dense sweep for the 16-bit lattices (steps of lsb/2 so every
        // other sample is an exact tie; all dyadic, so the accumulation
        // below is exact)…
        fn sweep(fmt: QFormat, q: impl Fn(f64) -> f64) {
            let lsb = fmt.lsb();
            let mut v = fmt.min_value() - 3.0 * lsb;
            let hi = fmt.max_value() + 3.0 * lsb;
            while v <= hi {
                assert_eq!(fmt.quantize(v), q(v), "fmt {fmt:?} v={v}");
                v += lsb / 2.0;
            }
            assert_eq!(fmt.quantize(f64::NAN), q(f64::NAN));
            assert_eq!(fmt.quantize(f64::INFINITY), q(f64::INFINITY));
            assert_eq!(fmt.quantize(f64::NEG_INFINITY), q(f64::NEG_INFINITY));
        }
        sweep(QFormat::q16(), |v| Fixed::<12>::from_f64(v).to_f64());
        sweep(QFormat::new(1, 14), |v| Fixed::<14>::from_f64(v).to_f64());
        // …and targeted probes (ties, rails, interior) for the 32-bit
        // lattices, where a dense sweep would take billions of steps.
        fn probe(fmt: QFormat, q: impl Fn(f64) -> f64) {
            let lsb = fmt.lsb();
            for v in [
                0.0,
                1.5 * lsb,
                2.5 * lsb,
                -1.5 * lsb,
                -2.5 * lsb,
                0.3,
                -1.7,
                fmt.max_value(),
                fmt.max_value() + lsb,
                fmt.min_value(),
                fmt.min_value() - lsb,
                1e30,
                -1e30,
                f64::NAN,
                f64::INFINITY,
                f64::NEG_INFINITY,
            ] {
                assert_eq!(fmt.quantize(v), q(v), "fmt {fmt:?} v={v}");
            }
        }
        probe(QFormat::q32(), |v| Fixed::<24>::from_f64(v).to_f64());
        probe(QFormat::new(3, 28), |v| Fixed::<28>::from_f64(v).to_f64());
        let _ = crate::qfx::take_saturation_events();
    }

    #[test]
    fn exact_lattice_formats_run_the_qfx_kernels() {
        // QFormat::q16() must route through the same fused fixed-point
        // kernels the serving plane's q16 tenants run — pinned by stepping
        // a manual Fixed<12> twin and requiring bit-identical B.
        let ds = Dataset::standard(55, 4, 2, 500);
        let mut q =
            QuantizedEasi::with_identity_init(2, 4, 0.004, Nonlinearity::Cube, QFormat::q16());
        assert!(q.uses_qfx_kernels());
        assert!(!QuantizedEasi::with_identity_init(
            2,
            4,
            0.004,
            Nonlinearity::Cube,
            QFormat::new(3, 4)
        )
        .uses_qfx_kernels());
        let mut twin: Mat<Fixed<12>> = q.b().cast();
        let mu = Fixed::<12>::from_f64(q.effective_mu());
        let mut s = FusedScratch::<Fixed<12>>::new(2, 4);
        for t in 0..ds.len() {
            q.step(ds.sample(t));
            let xq: Vec<Fixed<12>> =
                ds.sample(t).iter().map(|&v| Fixed::<12>::from_f64(v)).collect();
            fused::relative_gradient_step_into(&mut twin, &xq, |v| v * v * v, mu, &mut s);
        }
        let wide: Mat64 = twin.cast();
        assert_eq!(q.b().as_slice(), wide.as_slice());
        let _ = crate::qfx::take_saturation_events();
    }

    #[test]
    fn quantize_idempotent() {
        let fmt = QFormat::q16();
        for v in [-3.2, -0.001, 0.0, 0.7, 2.9] {
            let q = fmt.quantize(v);
            assert_eq!(fmt.quantize(q), q);
        }
    }

    #[test]
    fn word_bits_accounting() {
        assert_eq!(QFormat::q16().word_bits(), 16);
        assert_eq!(QFormat::q32().word_bits(), 32);
    }

    #[test]
    fn nan_quantizes_to_zero() {
        assert_eq!(QFormat::q16().quantize(f64::NAN), 0.0);
    }

    #[test]
    fn high_precision_matches_float_closely() {
        // Q7.24 should track the f64 reference tightly over a short run.
        let ds = Dataset::standard(51, 4, 2, 2_000);
        let xs = ds.x.map(|v| v / 3.0);
        let mut float = EasiSgd::with_identity_init(2, 4, 0.005, Nonlinearity::Cube);
        let mut fixed = QuantizedEasi::with_identity_init(
            2,
            4,
            0.005,
            Nonlinearity::Cube,
            QFormat::q32(),
        );
        for t in 0..xs.rows() {
            float.step(xs.row(t));
            fixed.step(xs.row(t));
        }
        assert!(
            float.b().max_abs_diff(fixed.b()) < 0.01,
            "Q7.24 drift {}",
            float.b().max_abs_diff(fixed.b())
        );
    }

    #[test]
    fn q16_still_separates_but_worse() {
        let ds = Dataset::standard(52, 4, 2, 60_000);
        let pow: f64 = ds.x.as_slice().iter().map(|v| v * v).sum::<f64>()
            / ds.x.as_slice().len() as f64;
        let xs = ds.x.map(|v| v / pow.sqrt());
        let mut fixed = QuantizedEasi::with_identity_init(
            2,
            4,
            0.004,
            Nonlinearity::Cube,
            QFormat::q16(),
        );
        let mut float = EasiSgd::with_identity_init(2, 4, 0.004, Nonlinearity::Cube);
        for t in 0..xs.rows() {
            fixed.step(xs.row(t));
            float.step(xs.row(t));
        }
        let a_fixed = amari_index(&fixed.b().matmul(&ds.a));
        let a_float = amari_index(&float.b().matmul(&ds.a));
        assert!(a_fixed < 0.35, "q16 should still roughly separate: {a_fixed}");
        assert!(
            a_float <= a_fixed + 0.02,
            "float ({a_float}) should be at least as good as q16 ({a_fixed})"
        );
    }

    #[test]
    fn tiny_words_fail_to_separate() {
        // 8-bit datapath: μ quantizes near/below an LSB and H saturates —
        // separation collapses. (The cliff the A4 ablation charts.)
        let ds = Dataset::standard(53, 4, 2, 30_000);
        let pow: f64 = ds.x.as_slice().iter().map(|v| v * v).sum::<f64>()
            / ds.x.as_slice().len() as f64;
        let xs = ds.x.map(|v| v / pow.sqrt());
        let mut q8 = QuantizedEasi::with_identity_init(
            2,
            4,
            0.004,
            Nonlinearity::Cube,
            QFormat::new(3, 4),
        );
        for t in 0..xs.rows() {
            q8.step(xs.row(t));
        }
        let a = amari_index(&q8.b().matmul(&ds.a));
        assert!(a > 0.15, "8-bit EASI should not separate cleanly: {a}");
    }

    #[test]
    fn b_stays_in_range() {
        let fmt = QFormat::q16();
        let ds = Dataset::standard(54, 4, 2, 5_000);
        let mut q = QuantizedEasi::with_identity_init(2, 4, 0.01, Nonlinearity::Cube, fmt);
        for t in 0..ds.len() {
            q.step(ds.sample(t));
        }
        let max = q.b().max_abs();
        // The negative two's-complement rail has the larger magnitude.
        assert!(max <= -fmt.min_value() + 1e-12, "saturation must bound B: {max}");
    }
}
