//! Fixed-point arithmetic simulation — the numeric substrate of the
//! *prior* FPGA implementations the paper compares against.
//!
//! Odom [12] implements EASI with 16-bit fixed-point variables; the paper
//! argues for 32-bit floating point ("a fair comparison of our work with
//! previous work is hard because our work uses 32-bit floating point...").
//! This module makes that argument testable: [`QFormat`] models signed
//! fixed-point with rounding + saturation, and [`QuantizedEasi`] runs the
//! EASI SGD update with *every* intermediate quantized, simulating the
//! fixed-point datapath bit-growth behaviour. The A4 ablation
//! (`cargo bench --bench ablation_quant`) sweeps word length and shows
//! where separation quality falls off a cliff.

use super::nonlinearity::Nonlinearity;
use super::Optimizer;
use crate::linalg::Mat64;

/// Signed fixed-point format Q`int_bits`.`frac_bits` (plus sign bit).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QFormat {
    /// Integer bits (excluding sign).
    pub int_bits: u32,
    /// Fractional bits.
    pub frac_bits: u32,
}

impl QFormat {
    /// Common shorthand: total word length with `int_bits` integer bits.
    /// `QFormat::new(3, 12)` is a 16-bit word (1 sign + 3 int + 12 frac).
    pub const fn new(int_bits: u32, frac_bits: u32) -> Self {
        Self { int_bits, frac_bits }
    }

    /// The 16-bit format of Odom [12]-style implementations (Q3.12).
    pub const fn q16() -> Self {
        Self::new(3, 12)
    }

    /// A 32-bit fixed-point format (Q7.24).
    pub const fn q32() -> Self {
        Self::new(7, 24)
    }

    /// Total word length including the sign bit.
    pub fn word_bits(&self) -> u32 {
        1 + self.int_bits + self.frac_bits
    }

    /// Largest representable magnitude.
    pub fn max_value(&self) -> f64 {
        let scale = (1u64 << self.frac_bits) as f64;
        (((1u64 << (self.int_bits + self.frac_bits)) - 1) as f64) / scale
    }

    /// Resolution (value of one LSB).
    pub fn lsb(&self) -> f64 {
        1.0 / (1u64 << self.frac_bits) as f64
    }

    /// Quantize: round-to-nearest at `frac_bits`, saturate to the range.
    /// (Saturation, not wraparound — the standard DSP datapath choice.)
    pub fn quantize(&self, v: f64) -> f64 {
        if v.is_nan() {
            return 0.0;
        }
        let scale = (1u64 << self.frac_bits) as f64;
        let max = self.max_value();
        (v.clamp(-max, max) * scale).round() / scale
    }

    /// Quantize a slice in place.
    pub fn quantize_slice(&self, xs: &mut [f64]) {
        xs.iter_mut().for_each(|v| *v = self.quantize(*v));
    }

    /// Quantize a matrix in place.
    pub fn quantize_mat(&self, m: &mut Mat64) {
        self.quantize_slice(m.as_mut_slice());
    }
}

/// EASI SGD with a fully-quantized datapath: inputs, `y`, `g(y)`, every
/// `H` entry, the `μHB` product and the stored `B` all live in `fmt`.
///
/// This mirrors what a fixed-point FPGA implementation computes: each
/// operator output is rounded/saturated before feeding the next stage.
pub struct QuantizedEasi {
    b: Mat64,
    mu: f64,
    g: Nonlinearity,
    fmt: QFormat,
    samples: u64,
    // Scratch
    y: Vec<f64>,
    gy: Vec<f64>,
    h: Mat64,
    hb: Mat64,
    xq: Vec<f64>,
}

impl QuantizedEasi {
    pub fn new(mut b0: Mat64, mu: f64, g: Nonlinearity, fmt: QFormat) -> Self {
        assert!(mu > 0.0);
        fmt.quantize_mat(&mut b0);
        let (n, m) = b0.shape();
        Self {
            mu: fmt.quantize(mu).max(fmt.lsb()), // μ below 1 LSB freezes learning
            g,
            fmt,
            samples: 0,
            y: vec![0.0; n],
            gy: vec![0.0; n],
            h: Mat64::zeros(n, n),
            hb: Mat64::zeros(n, m),
            xq: vec![0.0; m],
            b: b0,
        }
    }

    pub fn with_identity_init(n: usize, m: usize, mu: f64, g: Nonlinearity, fmt: QFormat) -> Self {
        let mut b0 = Mat64::eye(n, m);
        b0.scale(0.5);
        Self::new(b0, mu, g, fmt)
    }

    pub fn format(&self) -> QFormat {
        self.fmt
    }

    /// The effective learning rate after quantization.
    pub fn effective_mu(&self) -> f64 {
        self.mu
    }
}

impl Optimizer for QuantizedEasi {
    fn step(&mut self, x: &[f64]) {
        let fmt = self.fmt;
        // Input quantization (ADC).
        self.xq.copy_from_slice(x);
        fmt.quantize_slice(&mut self.xq);

        // y = Bx, quantized after the accumulate.
        self.b.matvec_into(&self.xq, &mut self.y);
        fmt.quantize_slice(&mut self.y);

        // g(y), quantized.
        self.g.apply_slice(&self.y, &mut self.gy);
        fmt.quantize_slice(&mut self.gy);

        // H = yyᵀ − I + gyᵀ − ygᵀ, every entry quantized.
        let n = self.y.len();
        for i in 0..n {
            for j in 0..n {
                let mut v =
                    self.y[i] * self.y[j] + self.gy[i] * self.y[j] - self.y[i] * self.gy[j];
                if i == j {
                    v -= 1.0;
                }
                self.h[(i, j)] = fmt.quantize(v);
            }
        }

        // B ← B − μ(HB), products and the update quantized.
        self.h.matmul_into(&self.b, &mut self.hb);
        for (b, u) in self.b.as_mut_slice().iter_mut().zip(self.hb.as_slice()) {
            *b = fmt.quantize(*b - fmt.quantize(self.mu * *u));
        }
        self.samples += 1;
    }

    fn b(&self) -> &Mat64 {
        &self.b
    }

    fn b_mut(&mut self) -> &mut Mat64 {
        &mut self.b
    }

    fn samples_seen(&self) -> u64 {
        self.samples
    }

    fn name(&self) -> &'static str {
        "easi-sgd-fixed"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ica::{amari_index, EasiSgd};
    use crate::signal::Dataset;

    #[test]
    fn quantize_rounds_to_lsb() {
        let fmt = QFormat::new(3, 4); // LSB = 1/16
        assert_eq!(fmt.quantize(0.06), 0.0625);
        assert_eq!(fmt.quantize(0.03), 0.0); // below LSB/2: rounds to zero
        assert_eq!(fmt.quantize(-0.06), -0.0625);
    }

    #[test]
    fn quantize_saturates() {
        let fmt = QFormat::new(2, 4); // max ≈ 3.9375
        assert_eq!(fmt.quantize(100.0), fmt.max_value());
        assert_eq!(fmt.quantize(-100.0), -fmt.max_value());
    }

    #[test]
    fn quantize_idempotent() {
        let fmt = QFormat::q16();
        for v in [-3.2, -0.001, 0.0, 0.7, 2.9] {
            let q = fmt.quantize(v);
            assert_eq!(fmt.quantize(q), q);
        }
    }

    #[test]
    fn word_bits_accounting() {
        assert_eq!(QFormat::q16().word_bits(), 16);
        assert_eq!(QFormat::q32().word_bits(), 32);
    }

    #[test]
    fn nan_quantizes_to_zero() {
        assert_eq!(QFormat::q16().quantize(f64::NAN), 0.0);
    }

    #[test]
    fn high_precision_matches_float_closely() {
        // Q7.24 should track the f64 reference tightly over a short run.
        let ds = Dataset::standard(51, 4, 2, 2_000);
        let xs = ds.x.map(|v| v / 3.0);
        let mut float = EasiSgd::with_identity_init(2, 4, 0.005, Nonlinearity::Cube);
        let mut fixed = QuantizedEasi::with_identity_init(
            2,
            4,
            0.005,
            Nonlinearity::Cube,
            QFormat::q32(),
        );
        for t in 0..xs.rows() {
            float.step(xs.row(t));
            fixed.step(xs.row(t));
        }
        assert!(
            float.b().max_abs_diff(fixed.b()) < 0.01,
            "Q7.24 drift {}",
            float.b().max_abs_diff(fixed.b())
        );
    }

    #[test]
    fn q16_still_separates_but_worse() {
        let ds = Dataset::standard(52, 4, 2, 60_000);
        let pow: f64 = ds.x.as_slice().iter().map(|v| v * v).sum::<f64>()
            / ds.x.as_slice().len() as f64;
        let xs = ds.x.map(|v| v / pow.sqrt());
        let mut fixed = QuantizedEasi::with_identity_init(
            2,
            4,
            0.004,
            Nonlinearity::Cube,
            QFormat::q16(),
        );
        let mut float = EasiSgd::with_identity_init(2, 4, 0.004, Nonlinearity::Cube);
        for t in 0..xs.rows() {
            fixed.step(xs.row(t));
            float.step(xs.row(t));
        }
        let a_fixed = amari_index(&fixed.b().matmul(&ds.a));
        let a_float = amari_index(&float.b().matmul(&ds.a));
        assert!(a_fixed < 0.35, "q16 should still roughly separate: {a_fixed}");
        assert!(
            a_float <= a_fixed + 0.02,
            "float ({a_float}) should be at least as good as q16 ({a_fixed})"
        );
    }

    #[test]
    fn tiny_words_fail_to_separate() {
        // 8-bit datapath: μ quantizes near/below an LSB and H saturates —
        // separation collapses. (The cliff the A4 ablation charts.)
        let ds = Dataset::standard(53, 4, 2, 30_000);
        let pow: f64 = ds.x.as_slice().iter().map(|v| v * v).sum::<f64>()
            / ds.x.as_slice().len() as f64;
        let xs = ds.x.map(|v| v / pow.sqrt());
        let mut q8 = QuantizedEasi::with_identity_init(
            2,
            4,
            0.004,
            Nonlinearity::Cube,
            QFormat::new(3, 4),
        );
        for t in 0..xs.rows() {
            q8.step(xs.row(t));
        }
        let a = amari_index(&q8.b().matmul(&ds.a));
        assert!(a > 0.15, "8-bit EASI should not separate cleanly: {a}");
    }

    #[test]
    fn b_stays_in_range() {
        let fmt = QFormat::q16();
        let ds = Dataset::standard(54, 4, 2, 5_000);
        let mut q = QuantizedEasi::with_identity_init(2, 4, 0.01, Nonlinearity::Cube, fmt);
        for t in 0..ds.len() {
            q.step(ds.sample(t));
        }
        let max = q.b().max_abs();
        assert!(max <= fmt.max_value() + 1e-12, "saturation must bound B: {max}");
    }
}
