//! Separation-quality metrics.
//!
//! All metrics operate on the **global matrix** `C = B·A` (n × n): perfect
//! separation makes C a scaled permutation matrix. The convergence
//! experiments (E1) and the adaptive-tracking bench (A3) use the Amari
//! index; SIR is reported by the examples for interpretability.

use crate::linalg::Mat64;

/// Amari performance index of the global matrix `C = B A`.
///
/// ```text
///   PI(C) = 1/(2n(n−1)) · [ Σᵢ ( Σⱼ |cᵢⱼ| / maxⱼ|cᵢⱼ| − 1 )
///                         + Σⱼ ( Σᵢ |cᵢⱼ| / maxᵢ|cᵢⱼ| − 1 ) ]
/// ```
///
/// 0 for a scaled permutation (perfect separation); O(1) for a random C.
/// Mirrors `ref.amari_index` in the Python oracle.
pub fn amari_index(c: &Mat64) -> f64 {
    let n = c.rows();
    assert_eq!(c.cols(), n, "amari_index needs square C (global matrix)");
    assert!(n >= 2, "amari_index undefined for n < 2");

    let mut total = 0.0;
    // Row term.
    for i in 0..n {
        let row = c.row(i);
        let max = row.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        if max == 0.0 {
            return f64::INFINITY; // degenerate: a source is lost entirely
        }
        let sum: f64 = row.iter().map(|v| v.abs()).sum();
        total += sum / max - 1.0;
    }
    // Column term.
    for j in 0..n {
        let mut max = 0.0f64;
        let mut sum = 0.0f64;
        for i in 0..n {
            let v = c[(i, j)].abs();
            max = max.max(v);
            sum += v;
        }
        if max == 0.0 {
            return f64::INFINITY;
        }
        total += sum / max - 1.0;
    }
    total / (2.0 * n as f64 * (n as f64 - 1.0))
}

/// Inter-symbol-interference index: like Amari but normalizing by the
/// total power rather than row sums — another standard BSS metric.
pub fn isi(c: &Mat64) -> f64 {
    let n = c.rows();
    assert_eq!(c.cols(), n);
    let mut total = 0.0;
    for i in 0..n {
        let row = c.row(i);
        let max2 = row.iter().fold(0.0f64, |m, v| m.max(v * v));
        if max2 == 0.0 {
            return f64::INFINITY;
        }
        let sum2: f64 = row.iter().map(|v| v * v).sum();
        total += sum2 / max2 - 1.0;
    }
    // One column scratch reused across j (Mat::col allocates per call).
    let mut col = vec![0.0; n];
    for j in 0..n {
        c.col_into(j, &mut col);
        let max2 = col.iter().fold(0.0f64, |m, v| m.max(v * v));
        if max2 == 0.0 {
            return f64::INFINITY;
        }
        let sum2: f64 = col.iter().map(|v| v * v).sum();
        total += sum2 / max2 - 1.0;
    }
    total / (2.0 * n as f64 * (n as f64 - 1.0))
}

/// Mean signal-to-interference ratio (dB) across recovered components:
/// for each row of C, the power of the dominant entry over the rest.
pub fn sir_db(c: &Mat64) -> f64 {
    let n = c.rows();
    assert_eq!(c.cols(), n);
    let mut acc = 0.0;
    for i in 0..n {
        let row = c.row(i);
        let max2 = row.iter().fold(0.0f64, |m, v| m.max(v * v));
        let sum2: f64 = row.iter().map(|v| v * v).sum();
        let interference = (sum2 - max2).max(1e-300);
        acc += 10.0 * (max2 / interference).log10();
    }
    acc / n as f64
}

/// Greedy permutation-and-sign matching between recovered signals `y`
/// (T × n) and ground truth `s` (T × n): returns mean |corr| over matched
/// pairs ∈ [0, 1]. Used by the examples to report "how much of each
/// source was recovered" without access to A.
pub fn matched_abs_correlation(y: &Mat64, s: &Mat64) -> f64 {
    assert_eq!(y.rows(), s.rows(), "matched correlation: sample counts differ");
    let n = y.cols().min(s.cols());
    let t = y.rows() as f64;

    // Column means/stds.
    let stats = |m: &Mat64, j: usize| -> (f64, f64) {
        let mut mean = 0.0;
        for i in 0..m.rows() {
            mean += m[(i, j)];
        }
        mean /= t;
        let mut var = 0.0;
        for i in 0..m.rows() {
            var += (m[(i, j)] - mean).powi(2);
        }
        (mean, (var / t).sqrt().max(1e-300))
    };

    // |corr| matrix.
    let mut corr = Mat64::zeros(n, n);
    for a in 0..n {
        let (my, sy) = stats(y, a);
        for b in 0..n {
            let (ms, ss) = stats(s, b);
            let mut c = 0.0;
            for i in 0..y.rows() {
                c += (y[(i, a)] - my) * (s[(i, b)] - ms);
            }
            corr[(a, b)] = (c / t / (sy * ss)).abs();
        }
    }

    // Greedy assignment (n ≤ 16: fine vs Hungarian).
    let mut used_y = vec![false; n];
    let mut used_s = vec![false; n];
    let mut total = 0.0;
    for _ in 0..n {
        let mut best = (0, 0, -1.0);
        for a in 0..n {
            if used_y[a] {
                continue;
            }
            for b in 0..n {
                if used_s[b] {
                    continue;
                }
                if corr[(a, b)] > best.2 {
                    best = (a, b, corr[(a, b)]);
                }
            }
        }
        used_y[best.0] = true;
        used_s[best.1] = true;
        total += best.2;
    }
    total / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::signal::Pcg32;
    use crate::testkit::{check, Config};

    #[test]
    fn amari_zero_for_identity() {
        assert!(amari_index(&Mat64::eye(3, 3)) < 1e-12);
    }

    #[test]
    fn amari_zero_for_scaled_permutation() {
        // C = scaled permutation with sign flips.
        let c = Mat64::from_rows(&[
            &[0.0, -2.5, 0.0],
            &[0.7, 0.0, 0.0],
            &[0.0, 0.0, 3.0],
        ]);
        assert!(amari_index(&c) < 1e-12);
        assert!(isi(&c) < 1e-12);
    }

    #[test]
    fn amari_positive_for_mixing() {
        let c = Mat64::from_rows(&[&[1.0, 0.5], &[0.5, 1.0]]);
        let a = amari_index(&c);
        assert!(a > 0.4, "amari {a}");
    }

    #[test]
    fn amari_invariant_to_permutation_and_sign() {
        // (General row *scaling* is not an invariance of the index — only
        // permutations and sign flips are; scaled permutations still map
        // to exactly 0 because each row/col has a single nonzero.)
        check("amari perm/sign invariant", Config::quick(), |rng| {
            let n = 3;
            let c = Mat64::from_fn(n, n, |_, _| rng.normal());
            let base = amari_index(&c);
            let signs = [1.0, -1.0, -1.0];
            let c2 = Mat64::from_fn(n, n, |i, j| c[((i + 1) % n, j)] * signs[i]);
            (amari_index(&c2) - base).abs() < 1e-12
        });
    }

    #[test]
    fn amari_worst_case_uniform_matrix() {
        let n = 4;
        let c = Mat64::from_fn(n, n, |_, _| 1.0);
        // Every row sums to n with max 1 ⇒ index = (n−1)·2n/(2n(n−1)) = 1.
        assert!((amari_index(&c) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn amari_degenerate_row_is_infinite() {
        let c = Mat64::from_rows(&[&[0.0, 0.0], &[1.0, 1.0]]);
        assert!(amari_index(&c).is_infinite());
    }

    #[test]
    fn sir_large_for_separation() {
        let c = Mat64::from_rows(&[&[1.0, 1e-4], &[1e-4, -2.0]]);
        assert!(sir_db(&c) > 60.0);
        let mixed = Mat64::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]);
        assert!(sir_db(&mixed) < 1.0);
    }

    #[test]
    fn matched_correlation_perfect_for_permuted_scaled_copy() {
        let mut rng = Pcg32::seed(3);
        let t = 500;
        let s = Mat64::from_fn(t, 2, |_, _| rng.normal());
        // y = swapped and scaled copy of s
        let y = Mat64::from_fn(t, 2, |i, j| if j == 0 { -3.0 * s[(i, 1)] } else { 0.5 * s[(i, 0)] });
        let c = matched_abs_correlation(&y, &s);
        assert!(c > 0.999, "corr {c}");
    }

    #[test]
    fn matched_correlation_low_for_independent() {
        let mut rng = Pcg32::seed(4);
        let t = 2000;
        let s = Mat64::from_fn(t, 2, |_, _| rng.normal());
        let y = Mat64::from_fn(t, 2, |_, _| rng.normal());
        let c = matched_abs_correlation(&y, &s);
        assert!(c < 0.1, "corr {c}");
    }

    #[test]
    fn isi_agrees_with_amari_on_ranking() {
        let good = Mat64::from_rows(&[&[1.0, 0.1], &[-0.1, 1.0]]);
        let bad = Mat64::from_rows(&[&[1.0, 0.8], &[0.9, 1.0]]);
        assert!(amari_index(&good) < amari_index(&bad));
        assert!(isi(&good) < isi(&bad));
    }
}
