//! Convergence detection and the §V.A experiment driver.
//!
//! The paper's convergence experiment (E1): run the same separation
//! problem from many random initial separation matrices, count the
//! iterations (samples) until the separator is "converged", and average.
//! Convergence here is operationalized as the Amari index of the global
//! matrix `C = B·A` staying below a threshold for `patience` consecutive
//! checks (the paper does not state its criterion; this one is standard
//! and applied identically to both optimizers, which is what the 24%
//! relative claim needs).

use super::metrics::amari_index;
use super::Optimizer;
use crate::linalg::Mat64;

/// When do we declare convergence?
#[derive(Clone, Copy, Debug)]
pub struct ConvergenceCriterion {
    /// Amari-index threshold.
    pub threshold: f64,
    /// Evaluate the index every this many samples.
    pub check_every: usize,
    /// Require this many consecutive sub-threshold checks.
    pub patience: usize,
}

impl Default for ConvergenceCriterion {
    fn default() -> Self {
        Self { threshold: 0.08, check_every: 50, patience: 3 }
    }
}

/// Outcome of one convergence run.
#[derive(Clone, Copy, Debug)]
pub struct ConvergenceReport {
    /// Did the run converge within the sample budget?
    pub converged: bool,
    /// Samples consumed until the *first* check of the converged streak
    /// (the paper's "number of iterations").
    pub iterations: u64,
    /// Amari index at the end of the run.
    pub final_amari: f64,
}

/// Drive `opt` over the sample stream `xs` (row-major T × m) until the
/// criterion fires, measuring the Amari index against the true mixing `a`
/// (m × n). Returns the iterations-to-convergence report.
pub fn run_to_convergence(
    opt: &mut dyn Optimizer,
    xs: &Mat64,
    a: &Mat64,
    criterion: ConvergenceCriterion,
) -> ConvergenceReport {
    let t_max = xs.rows();
    let mut streak = 0usize;
    let mut streak_start: u64 = 0;
    let mut last_amari = f64::INFINITY;

    for t in 0..t_max {
        opt.step(xs.row(t));
        if (t + 1) % criterion.check_every == 0 {
            let c = opt.b().matmul(a);
            last_amari = amari_index(&c);
            if last_amari < criterion.threshold {
                if streak == 0 {
                    streak_start = (t + 1) as u64;
                }
                streak += 1;
                if streak >= criterion.patience {
                    return ConvergenceReport {
                        converged: true,
                        iterations: streak_start,
                        final_amari: last_amari,
                    };
                }
            } else {
                streak = 0;
            }
        }
    }
    ConvergenceReport { converged: false, iterations: t_max as u64, final_amari: last_amari }
}

/// Aggregate of a multi-seed convergence study (one optimizer).
#[derive(Clone, Debug)]
pub struct ConvergenceStudy {
    pub runs: Vec<ConvergenceReport>,
}

impl ConvergenceStudy {
    /// Mean iterations over *converged* runs (the paper's statistic).
    pub fn mean_iterations(&self) -> f64 {
        let conv: Vec<_> = self.runs.iter().filter(|r| r.converged).collect();
        if conv.is_empty() {
            return f64::NAN;
        }
        conv.iter().map(|r| r.iterations as f64).sum::<f64>() / conv.len() as f64
    }

    /// Fraction of runs that converged within budget.
    pub fn convergence_rate(&self) -> f64 {
        if self.runs.is_empty() {
            return 0.0;
        }
        self.runs.iter().filter(|r| r.converged).count() as f64 / self.runs.len() as f64
    }

    /// Sample standard deviation of iterations over converged runs.
    pub fn std_iterations(&self) -> f64 {
        let conv: Vec<f64> = self
            .runs
            .iter()
            .filter(|r| r.converged)
            .map(|r| r.iterations as f64)
            .collect();
        if conv.len() < 2 {
            return 0.0;
        }
        let mean = conv.iter().sum::<f64>() / conv.len() as f64;
        (conv.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / (conv.len() as f64 - 1.0))
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ica::{EasiSgd, Nonlinearity};
    use crate::signal::Dataset;

    fn normalized_x(ds: &Dataset) -> Mat64 {
        let s: f64 = ds.x.as_slice().iter().map(|v| v * v).sum();
        let std = (s / ds.x.as_slice().len() as f64).sqrt();
        ds.x.map(|v| v / std)
    }

    #[test]
    fn sgd_converges_and_reports_iterations() {
        let ds = Dataset::standard(31, 4, 2, 80_000);
        let xs = normalized_x(&ds);
        let mut opt = EasiSgd::with_identity_init(2, 4, 0.004, Nonlinearity::Cube);
        let rep = run_to_convergence(
            &mut opt,
            &xs,
            &ds.a,
            ConvergenceCriterion::default(),
        );
        assert!(rep.converged, "should converge: final {}", rep.final_amari);
        assert!(rep.iterations > 100, "not instant: {}", rep.iterations);
        assert!(rep.iterations < 80_000);
    }

    #[test]
    fn impossible_threshold_never_converges() {
        let ds = Dataset::standard(32, 4, 2, 2_000);
        let xs = normalized_x(&ds);
        let mut opt = EasiSgd::with_identity_init(2, 4, 0.004, Nonlinearity::Cube);
        let crit = ConvergenceCriterion { threshold: 1e-12, ..Default::default() };
        let rep = run_to_convergence(&mut opt, &xs, &ds.a, crit);
        assert!(!rep.converged);
        assert_eq!(rep.iterations, 2_000);
    }

    #[test]
    fn study_statistics() {
        let study = ConvergenceStudy {
            runs: vec![
                ConvergenceReport { converged: true, iterations: 100, final_amari: 0.01 },
                ConvergenceReport { converged: true, iterations: 300, final_amari: 0.02 },
                ConvergenceReport { converged: false, iterations: 1000, final_amari: 0.5 },
            ],
        };
        assert_eq!(study.mean_iterations(), 200.0);
        assert!((study.convergence_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!((study.std_iterations() - 141.4213562).abs() < 1e-3);
    }

    #[test]
    fn empty_study_is_nan() {
        let study = ConvergenceStudy { runs: vec![] };
        assert!(study.mean_iterations().is_nan());
        assert_eq!(study.convergence_rate(), 0.0);
    }
}
