//! SMBGD — the paper's contribution (§IV, Eq. 1, Fig. 2).
//!
//! Sequential mini-batch gradient descent accumulates the EASI relative
//! gradient over a mini-batch with exponentially-decaying intra-batch
//! weights (β), carries a cross-batch momentum term (γ), and applies the
//! separation-matrix update **once per mini-batch**:
//!
//! ```text
//!   p = 0:      Ĥ ← γ Ĥ_prev + μ H(B, x₀)
//!   0 < p < P:  Ĥ ← β Ĥ      + μ H(B, x_p)
//!   p = P:      B ← B − Ĥ B;  Ĥ_prev ← Ĥ;  p ← 0
//! ```
//!
//! Every `H(B, x_p)` inside a mini-batch uses the *same* (stale) `B` —
//! this is what breaks the loop-carried dependency and lets the FPGA
//! pipeline (and, at Layer 1, the TPU MXU batch) run at initiation
//! interval 1. This struct is the cycle-exact software model of Fig. 2;
//! the batched closed form lives in the Pallas kernel
//! (`python/compile/kernels/easi.py`) and both are pinned together by
//! parity tests (`rust/tests/parity_pjrt.rs`).

use super::nonlinearity::{with_g, Nonlinearity};
use super::Optimizer;
use crate::linalg::{fused, FusedScratch, Mat, Mat64, Scalar};

/// SMBGD hyperparameters (paper §IV notation).
#[derive(Clone, Copy, Debug)]
pub struct SmbgdParams {
    /// Learning rate μ.
    pub mu: f64,
    /// Cross-batch momentum coefficient γ ∈ [0, 1].
    pub gamma: f64,
    /// Intra-batch decay coefficient β ∈ (0, 1].
    pub beta: f64,
    /// Mini-batch size P ≥ 1.
    pub p: usize,
}

impl Default for SmbgdParams {
    fn default() -> Self {
        Self { mu: 0.002, gamma: 0.5, beta: 0.9, p: 8 }
    }
}

impl SmbgdParams {
    pub fn validate(&self) {
        assert!(self.mu > 0.0, "mu must be positive");
        assert!((0.0..=1.0).contains(&self.gamma), "gamma in [0,1]");
        assert!(self.beta > 0.0 && self.beta <= 1.0, "beta in (0,1]");
        assert!(self.p >= 1, "P >= 1");
    }

    /// Learning rate for an SGD run that matches this SMBGD configuration's
    /// *average per-sample gradient weight* — used by the convergence
    /// experiment (E1) for a fair comparison: SMBGD applies total weight
    /// `μ·Σβ^(P−1−p)` per mini-batch of P samples, i.e. an average of
    /// `μ·(1−β^P)/(P(1−β))` per sample (times the 1/(1−γβ^{P−1})
    /// steady-state momentum amplification).
    pub fn equivalent_sgd_mu(&self) -> f64 {
        let pf = self.p as f64;
        let batch_weight = if (1.0 - self.beta).abs() < 1e-12 {
            pf
        } else {
            (1.0 - self.beta.powi(self.p as i32)) / (1.0 - self.beta)
        };
        let momentum_gain = 1.0 / (1.0 - self.gamma * self.beta.powi(self.p as i32 - 1));
        self.mu * batch_weight * momentum_gain / pf
    }
}

/// EASI with SMBGD (Fig. 2) — sample-sequential model of the pipelined
/// hardware. Generic over the [`Scalar`] precision (`Smbgd<f32>` is the
/// paper's 32-bit datapath; `Smbgd<f64>` the bit-exact reference).
pub struct Smbgd<T: Scalar = f64> {
    b: Mat<T>,
    params: SmbgdParams,
    g: Nonlinearity,
    samples: u64,
    /// Position within the current mini-batch (the paper's `p`).
    p_idx: usize,
    /// Completed (latched) mini-batch updates (the paper's `k`).
    batches: u64,
    /// The running accumulator Ĥ (the paper's Ĥₖᵖ).
    hhat: Mat<T>,
    /// Ĥ at the end of the previous mini-batch (the paper's Ĥₖ₋₁ᴾ).
    hhat_prev: Mat<T>,
    // Scratch
    scratch: FusedScratch<T>,
}

impl<T: Scalar> Smbgd<T> {
    pub fn new(b0: Mat<T>, params: SmbgdParams, g: Nonlinearity) -> Self {
        params.validate();
        let (n, m) = b0.shape();
        Self {
            params,
            g,
            samples: 0,
            p_idx: 0,
            batches: 0,
            hhat: Mat::zeros(n, n),
            hhat_prev: Mat::zeros(n, n),
            scratch: FusedScratch::new(n, m),
            b: b0,
        }
    }

    /// Identity-like warm start, matching [`super::EasiSgd::with_identity_init`].
    pub fn with_identity_init(n: usize, m: usize, params: SmbgdParams, g: Nonlinearity) -> Self {
        let mut b0 = Mat::<T>::eye(n, m);
        b0.scale(T::scalar_from_f64(0.5));
        Self::new(b0, params, g)
    }

    pub fn params(&self) -> SmbgdParams {
        self.params
    }

    /// Current accumulator (exposed for parity tests with the L1 kernel).
    pub fn hhat(&self) -> &Mat<T> {
        &self.hhat
    }

    /// Accumulator carried across mini-batches (Ĥₖ₋₁ᴾ).
    pub fn hhat_prev(&self) -> &Mat<T> {
        &self.hhat_prev
    }

    /// Number of completed mini-batches (the paper's `k`).
    ///
    /// Derived from the latched update counter, not from
    /// `samples / P`: the count must mean "B-updates actually applied",
    /// which an arithmetic derivation only coincidentally matches while
    /// `p_idx` mirrors `samples % P` — latching keeps it correct under
    /// any future re-phasing (mid-batch resets, changed batch sizes).
    pub fn minibatches_done(&self) -> u64 {
        self.batches
    }

    /// True if the next `step` starts a new mini-batch.
    pub fn at_batch_boundary(&self) -> bool {
        self.p_idx == 0
    }

    /// Process one whole mini-batch (`xs` rows `start .. start+P`) through
    /// the fused block kernels. Requires `p_idx == 0`; bit-identical to P
    /// successive [`Optimizer::step`] calls, but the nonlinearity dispatch
    /// and loop setup happen once and the `Ĥ·B` matmul is applied by the
    /// fused update kernel — the software shape of the paper's pipelined
    /// mini-batch datapath (Fig. 2).
    fn block_step(&mut self, xs: &Mat<T>, start: usize) {
        debug_assert_eq!(self.p_idx, 0, "block_step mid-batch");
        let prm = self.params;
        let (mu, gamma, beta) = (
            T::scalar_from_f64(prm.mu),
            T::scalar_from_f64(prm.gamma),
            T::scalar_from_f64(prm.beta),
        );
        // Ĥ ← γ Ĥ_prev  (Eq. 1, p = 0)
        self.hhat.copy_from(&self.hhat_prev);
        self.hhat.scale(gamma);
        // Ĥ ← β Ĥ + μ H(B, x_p) for each sample, at the stale B (Eq. 1).
        let (b, hhat, s) = (&self.b, &mut self.hhat, &mut self.scratch);
        let rows = start..start + prm.p;
        with_g!(T, self.g, gf => {
            fused::accumulate_gradient_block(b, xs, rows, gf, mu, beta, hhat, s);
        });
        // End of mini-batch: B ← B − Ĥ B, latch Ĥ for momentum.
        fused::apply_accumulated_update(&mut self.b, &self.hhat, -T::one(), &mut self.scratch.hb);
        self.hhat_prev.copy_from(&self.hhat);
        self.samples += prm.p as u64;
        self.batches += 1;
    }
}

impl<T: Scalar> Optimizer<T> for Smbgd<T> {
    /// Feed one sample; applies the B update when the mini-batch fills.
    ///
    /// Matches the hardware exactly: one sample enters the pipeline per
    /// call, the matrix update fires every P-th call.
    fn step(&mut self, x: &[T]) {
        // H(B, x_p) with the STALE B (unchanged within the mini-batch),
        // via the fused triangular gradient kernel.
        let (b, s) = (&self.b, &mut self.scratch);
        with_g!(T, self.g, gf => {
            fused::relative_gradient_into(b, x, gf, &mut s.y, &mut s.gy, &mut s.h);
        });
        let mu = T::scalar_from_f64(self.params.mu);

        // The μ·H folds go through the same fused::axpy_fold the block
        // kernel uses, so step_batch stays chunk-invariant under `fma`
        // too (contraction identical on both paths); on the default build
        // axpy_fold IS Mat::axpy, bit-identically.
        if self.p_idx == 0 {
            // Ĥ ← γ Ĥ_prev + μ H   (Eq. 1, p = 0; γ is 0 for k = 0 because
            // hhat_prev starts as the zero matrix.)
            self.hhat.copy_from(&self.hhat_prev);
            self.hhat.scale(T::scalar_from_f64(self.params.gamma));
            fused::axpy_fold(&mut self.hhat, mu, &self.scratch.h);
        } else {
            // Ĥ ← β Ĥ + μ H        (Eq. 1, 0 < p < P)
            self.hhat.scale(T::scalar_from_f64(self.params.beta));
            fused::axpy_fold(&mut self.hhat, mu, &self.scratch.h);
        }

        self.p_idx += 1;
        self.samples += 1;

        if self.p_idx == self.params.p {
            // End of mini-batch: B ← B − Ĥ B, latch Ĥ for momentum, reset.
            let (b, hb) = (&mut self.b, &mut self.scratch.hb);
            fused::apply_accumulated_update(b, &self.hhat, -T::one(), hb);
            self.hhat_prev.copy_from(&self.hhat);
            self.p_idx = 0;
            self.batches += 1;
        }
    }

    /// Batch feed: whole mini-batches go through the fused block kernel;
    /// a leading partial batch (if the chunk starts mid-batch) and the
    /// tail fall back to per-sample steps. Bit-identical to looping
    /// [`Optimizer::step`] regardless of how the stream is chunked
    /// (pinned by tests/fused_hotpath.rs), so the coordinator's chunking
    /// stays algorithmically invisible.
    fn step_batch(&mut self, xs: &Mat<T>) {
        let p = self.params.p;
        let rows = xs.rows();
        let mut t = 0;
        // Align to a mini-batch boundary.
        while t < rows && self.p_idx != 0 {
            self.step(xs.row(t));
            t += 1;
        }
        // Whole mini-batches: fused block path.
        while rows - t >= p {
            self.block_step(xs, t);
            t += p;
        }
        // Tail (partial mini-batch).
        while t < rows {
            self.step(xs.row(t));
            t += 1;
        }
    }

    fn b(&self) -> &Mat<T> {
        &self.b
    }

    fn b_mut(&mut self) -> &mut Mat<T> {
        &mut self.b
    }

    fn samples_seen(&self) -> u64 {
        self.samples
    }

    fn name(&self) -> &'static str {
        "easi-smbgd"
    }

    /// New μ takes effect from the next gradient accumulation; the Ĥ terms
    /// already accumulated keep the μ they were weighted with (matching
    /// the hardware, where μ is a coefficient-bank constant swapped
    /// between batches).
    fn set_mu(&mut self, mu: f64) {
        assert!(mu > 0.0);
        self.params.mu = mu;
    }

    /// SMBGD is cohort-eligible at batch boundaries: the stale-`B`
    /// mini-batch pipeline is *more* regular than SGD (lanes share the
    /// structure, differ only in `(Ĥ_prev, μ, γ, β)` accumulator state),
    /// and [`crate::linalg::CohortSmbgdState`] replays the fused block
    /// path per lane bit-for-bit. Mid-batch (`p_idx != 0` — a partial
    /// chunk left the stream unaligned) the tenant stays on the solo path
    /// until it realigns; the coordinator's native chunk size is a
    /// multiple of P, so this is the steady state, not the exception.
    fn cohort_smbgd(&self) -> Option<(SmbgdParams, Nonlinearity)> {
        if self.p_idx == 0 {
            Some((self.params, self.g))
        } else {
            None
        }
    }

    fn cohort_hhat_prev(&self) -> Mat64 {
        // Widening T → f64 is lossless; the cohort lane narrows back
        // per element, so the round trip is bit-exact.
        self.hhat_prev.cast()
    }

    fn cohort_sync_smbgd(&mut self, b: &Mat64, hhat_prev: &Mat64, rows: u64) {
        debug_assert_eq!(self.p_idx, 0, "cohort sync mid-batch");
        debug_assert_eq!(rows % self.params.p as u64, 0, "cohort sync partial batch");
        b.cast_into(&mut self.b);
        // At every batch boundary the solo invariant is Ĥ == Ĥ_prev
        // (the latch just ran), so install the latched accumulator as
        // both — a detach-to-disk snapshot cut here is bit-identical to
        // the solo run's.
        hhat_prev.cast_into(&mut self.hhat);
        hhat_prev.cast_into(&mut self.hhat_prev);
        self.samples += rows;
        self.batches += rows / self.params.p as u64;
    }

    fn save_state(&self, w: &mut crate::snapshot::SnapWriter) -> anyhow::Result<()> {
        // γ, β, P and g are config-time constants re-supplied at
        // reconstruction; μ is governed at runtime so it persists. The
        // accumulators are what make a mid-batch cut bit-exact.
        w.put_str(self.name());
        w.put_mat(&self.b);
        w.put_f64(self.params.mu);
        w.put_u64(self.samples);
        w.put_usize(self.p_idx);
        w.put_u64(self.batches);
        w.put_mat(&self.hhat);
        w.put_mat(&self.hhat_prev);
        Ok(())
    }

    fn load_state(&mut self, r: &mut crate::snapshot::SnapReader<'_>) -> anyhow::Result<()> {
        crate::snapshot::expect_tag(r, self.name())?;
        let b: Mat<T> = r.get_mat()?;
        anyhow::ensure!(
            b.shape() == self.b.shape(),
            "snapshot B is {:?}, session expects {:?}",
            b.shape(),
            self.b.shape()
        );
        self.b = b;
        self.params.mu = r.get_f64()?;
        self.samples = r.get_u64()?;
        self.p_idx = r.get_usize()?;
        anyhow::ensure!(
            self.p_idx < self.params.p,
            "snapshot mini-batch position {} is outside P = {}",
            self.p_idx,
            self.params.p
        );
        self.batches = r.get_u64()?;
        let hhat: Mat<T> = r.get_mat()?;
        let hhat_prev: Mat<T> = r.get_mat()?;
        anyhow::ensure!(
            hhat.shape() == self.hhat.shape() && hhat_prev.shape() == self.hhat_prev.shape(),
            "snapshot accumulator shape mismatch"
        );
        self.hhat = hhat;
        self.hhat_prev = hhat_prev;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ica::EasiSgd;
    use crate::linalg::Mat64;
    use crate::signal::{Dataset, Pcg32};

    fn params(mu: f64, gamma: f64, beta: f64, p: usize) -> SmbgdParams {
        SmbgdParams { mu, gamma, beta, p }
    }

    /// Literal Eq. 1 + batch update, reimplemented independently.
    fn oracle_run(
        b0: &Mat64,
        xs: &[Vec<f64>],
        prm: SmbgdParams,
        g: Nonlinearity,
    ) -> (Mat64, Mat64) {
        let n = b0.rows();
        let mut b = b0.clone();
        let mut hhat = Mat64::zeros(n, n);
        let mut hhat_prev = Mat64::zeros(n, n);
        let mut y = vec![0.0; n];
        let mut gy = vec![0.0; n];
        let mut h = Mat64::zeros(n, n);
        for (i, x) in xs.iter().enumerate() {
            let p = i % prm.p;
            EasiSgd::relative_gradient(&b, x, g, false, prm.mu, &mut y, &mut gy, &mut h);
            if p == 0 {
                hhat = hhat_prev.clone();
                hhat.scale(prm.gamma);
            } else {
                hhat.scale(prm.beta / 1.0);
            }
            hhat.axpy(prm.mu, &h);
            if p == prm.p - 1 {
                let upd = hhat.matmul(&b);
                b.axpy(-1.0, &upd);
                hhat_prev = hhat.clone();
            }
        }
        (b, hhat_prev)
    }

    #[test]
    fn matches_independent_oracle() {
        let mut rng = Pcg32::seed(1);
        let b0 = Mat64::from_fn(2, 4, |_, _| rng.normal() * 0.3);
        let xs: Vec<Vec<f64>> =
            (0..40).map(|_| (0..4).map(|_| rng.normal()).collect()).collect();
        let prm = params(0.01, 0.6, 0.9, 8);
        let mut opt = Smbgd::new(b0.clone(), prm, Nonlinearity::Cube);
        for x in &xs {
            opt.step(x);
        }
        let (want_b, want_hprev) = oracle_run(&b0, &xs, prm, Nonlinearity::Cube);
        assert!(opt.b().max_abs_diff(&want_b) < 1e-12);
        assert!(opt.hhat_prev().max_abs_diff(&want_hprev) < 1e-12);
    }

    #[test]
    fn b_frozen_within_minibatch() {
        let mut rng = Pcg32::seed(2);
        let prm = params(0.01, 0.5, 0.9, 8);
        let mut opt = Smbgd::with_identity_init(2, 4, prm, Nonlinearity::Cube);
        let b_before = opt.b().clone();
        for _ in 0..7 {
            let x: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
            opt.step(&x);
            assert_eq!(opt.b(), &b_before, "B must not move mid-batch");
        }
        let x: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
        opt.step(&x); // 8th sample: update fires
        assert!(opt.b().max_abs_diff(&b_before) > 0.0);
    }

    #[test]
    fn p1_gamma0_equals_sgd() {
        // P=1 and γ=0 degrade SMBGD to exactly per-sample SGD.
        let mut rng = Pcg32::seed(3);
        let b0 = Mat64::from_fn(2, 4, |_, _| rng.normal() * 0.3);
        let prm = params(0.004, 0.0, 0.9, 1);
        let mut smbgd = Smbgd::new(b0.clone(), prm, Nonlinearity::Cube);
        let mut sgd = EasiSgd::new(b0, 0.004, Nonlinearity::Cube);
        for _ in 0..200 {
            let x: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
            smbgd.step(&x);
            sgd.step(&x);
        }
        assert!(smbgd.b().max_abs_diff(sgd.b()) < 1e-12);
    }

    #[test]
    fn gamma_zero_forgets_previous_batch() {
        // With γ=0 the accumulator restarts each batch: running batch k's
        // samples alone (from the same B) gives the same Ĥ.
        let mut rng = Pcg32::seed(4);
        let prm = params(0.01, 0.0, 0.85, 4);
        let b0 = Mat64::from_fn(2, 4, |_, _| rng.normal() * 0.3);
        let xs1: Vec<Vec<f64>> =
            (0..4).map(|_| (0..4).map(|_| rng.normal()).collect()).collect();
        let xs2: Vec<Vec<f64>> =
            (0..4).map(|_| (0..4).map(|_| rng.normal()).collect()).collect();

        let mut two = Smbgd::new(b0.clone(), prm, Nonlinearity::Cube);
        for x in xs1.iter().chain(&xs2) {
            two.step(x);
        }
        // B after batch 1 (for the "alone" run we need the same stale B).
        let mut first = Smbgd::new(b0, prm, Nonlinearity::Cube);
        for x in &xs1 {
            first.step(x);
        }
        let mut alone = Smbgd::new(first.b().clone(), prm, Nonlinearity::Cube);
        for x in &xs2 {
            alone.step(x);
        }
        assert!(two.hhat_prev().max_abs_diff(alone.hhat_prev()) < 1e-12);
    }

    #[test]
    fn separates_static_mixture() {
        let ds = Dataset::standard(7, 4, 2, 60_000);
        let std_x = {
            let s: f64 = ds.x.as_slice().iter().map(|v| v * v).sum();
            (s / ds.x.as_slice().len() as f64).sqrt()
        };
        let prm = params(0.003, 0.5, 0.9, 8);
        let mut opt = Smbgd::with_identity_init(2, 4, prm, Nonlinearity::Cube);
        let mut x = vec![0.0; 4];
        for t in 0..ds.len() {
            for (i, v) in ds.sample(t).iter().enumerate() {
                x[i] = v / std_x;
            }
            opt.step(&x);
        }
        let c = opt.b().matmul(&ds.a);
        let amari = super::super::metrics::amari_index(&c);
        assert!(amari < 0.15, "amari {amari}");
    }

    #[test]
    fn minibatch_counters() {
        let prm = params(0.01, 0.5, 0.9, 4);
        let mut opt = Smbgd::with_identity_init(2, 4, prm, Nonlinearity::Cube);
        let x = [0.1, -0.2, 0.3, -0.4];
        assert!(opt.at_batch_boundary());
        for i in 1..=10 {
            opt.step(&x);
            assert_eq!(opt.samples_seen(), i as u64);
        }
        assert_eq!(opt.minibatches_done(), 2);
        assert!(!opt.at_batch_boundary());
    }

    #[test]
    fn minibatches_done_latches_on_update() {
        // Regression: the count must track *completed* B-updates exactly,
        // at boundaries and mid-batch alike — one increment per latch,
        // never a sample-arithmetic artifact.
        let prm = params(0.01, 0.5, 0.9, 4);
        let mut opt = Smbgd::with_identity_init(2, 4, prm, Nonlinearity::Cube);
        let mut rng = Pcg32::seed(11);
        let mut b_updates = 0u64;
        let mut prev_b = opt.b().clone();
        for i in 1..=13u64 {
            let x: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
            opt.step(&x);
            if opt.b() != &prev_b {
                b_updates += 1;
                prev_b = opt.b().clone();
            }
            assert_eq!(
                opt.minibatches_done(),
                b_updates,
                "after {i} samples (p_idx {})",
                if opt.at_batch_boundary() { 0 } else { i as usize % 4 }
            );
            assert_eq!(opt.at_batch_boundary(), i % 4 == 0);
        }
        assert_eq!(opt.minibatches_done(), 3);
        assert_eq!(opt.samples_seen(), 13);
    }

    #[test]
    fn equivalent_sgd_mu_sane() {
        // β=1, γ=0, any P: every sample weighted μ ⇒ equivalent μ is μ.
        let prm = params(0.01, 0.0, 1.0, 8);
        assert!((prm.equivalent_sgd_mu() - 0.01).abs() < 1e-12);
        // Momentum amplifies the effective rate.
        let with_momentum = params(0.01, 0.5, 1.0, 8);
        assert!(with_momentum.equivalent_sgd_mu() > 0.015);
    }

    #[test]
    #[should_panic(expected = "P >= 1")]
    fn zero_p_rejected() {
        let _ =
            Smbgd::<f64>::with_identity_init(2, 4, params(0.01, 0.5, 0.9, 0), Nonlinearity::Cube);
    }
}
