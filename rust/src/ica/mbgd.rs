//! Plain mini-batch gradient descent (MBGD) baseline (§IV discussion).
//!
//! MBGD averages the relative gradient over P samples (all evaluated at
//! the same stale B, like SMBGD) and applies `B ← B − μ H̄ B` once per
//! batch — *without* SMBGD's exponential intra-batch weighting or
//! cross-batch momentum. The paper argues MBGD suits GPUs (P parallel
//! replicas of the datapath) while SMBGD suits FPGAs (one pipelined
//! datapath); the FPGA resource model quantifies that in
//! `fpga::resources` (MBGD duplicates the datapath P×).

use super::nonlinearity::{with_g, Nonlinearity};
use super::Optimizer;
use crate::linalg::{fused, FusedScratch, Mat, Scalar};

/// EASI with plain mini-batch averaging. Generic over the [`Scalar`]
/// precision like its siblings (`Mbgd<f32>` is the GPU-style datapath at
/// the paper's 32-bit width; `Mbgd<f64>` the bit-exact reference).
pub struct Mbgd<T: Scalar = f64> {
    b: Mat<T>,
    mu: f64,
    p: usize,
    g: Nonlinearity,
    samples: u64,
    p_idx: usize,
    /// Running sum of H over the current batch.
    hsum: Mat<T>,
    // Scratch
    scratch: FusedScratch<T>,
}

impl<T: Scalar> Mbgd<T> {
    pub fn new(b0: Mat<T>, mu: f64, p: usize, g: Nonlinearity) -> Self {
        assert!(mu > 0.0 && p >= 1);
        let (n, m) = b0.shape();
        Self {
            mu,
            p,
            g,
            samples: 0,
            p_idx: 0,
            hsum: Mat::zeros(n, n),
            scratch: FusedScratch::new(n, m),
            b: b0,
        }
    }

    pub fn with_identity_init(n: usize, m: usize, mu: f64, p: usize, g: Nonlinearity) -> Self {
        let mut b0 = Mat::<T>::eye(n, m);
        b0.scale(T::scalar_from_f64(0.5));
        Self::new(b0, mu, p, g)
    }

    pub fn batch_size(&self) -> usize {
        self.p
    }

    /// `−μ/P`, narrowed the same way both update paths need it.
    fn batch_alpha(&self) -> T {
        T::scalar_from_f64(-self.mu / self.p as f64)
    }
}

impl<T: Scalar> Optimizer<T> for Mbgd<T> {
    fn step(&mut self, x: &[T]) {
        let (b, s) = (&self.b, &mut self.scratch);
        with_g!(T, self.g, gf => {
            fused::relative_gradient_into(b, x, gf, &mut s.y, &mut s.gy, &mut s.h);
        });
        // Same fold as the block kernel (bit-identical at alpha = 1 under
        // every feature set), keeping step_batch chunk-invariant.
        fused::axpy_fold(&mut self.hsum, T::one(), &self.scratch.h);
        self.p_idx += 1;
        self.samples += 1;
        if self.p_idx == self.p {
            // B ← B − μ (ΣH / P) B
            let alpha = self.batch_alpha();
            fused::apply_accumulated_update(&mut self.b, &self.hsum, alpha, &mut self.scratch.hb);
            self.hsum.fill(T::zero());
            self.p_idx = 0;
        }
    }

    /// Batch feed: whole mini-batches accumulate through the fused block
    /// kernel (unit weight, no decay) with one update application per
    /// batch; alignment and tail fall back to per-sample steps.
    /// Bit-identical to looping [`Optimizer::step`] for any chunking.
    fn step_batch(&mut self, xs: &Mat<T>) {
        let rows = xs.rows();
        let mut t = 0;
        while t < rows && self.p_idx != 0 {
            self.step(xs.row(t));
            t += 1;
        }
        while rows - t >= self.p {
            let (b, hsum, s) = (&self.b, &mut self.hsum, &mut self.scratch);
            with_g!(T, self.g, gf => {
                fused::accumulate_gradient_block(
                    b, xs, t..t + self.p, gf, T::one(), T::one(), hsum, s,
                );
            });
            let alpha = self.batch_alpha();
            fused::apply_accumulated_update(&mut self.b, &self.hsum, alpha, &mut self.scratch.hb);
            self.hsum.fill(T::zero());
            self.samples += self.p as u64;
            t += self.p;
        }
        while t < rows {
            self.step(xs.row(t));
            t += 1;
        }
    }

    fn b(&self) -> &Mat<T> {
        &self.b
    }

    fn b_mut(&mut self) -> &mut Mat<T> {
        &mut self.b
    }

    fn samples_seen(&self) -> u64 {
        self.samples
    }

    fn name(&self) -> &'static str {
        "easi-mbgd"
    }

    /// New μ takes effect at the next batch-update application (`−μ/P`
    /// is evaluated when the batch completes).
    fn set_mu(&mut self, mu: f64) {
        assert!(mu > 0.0);
        self.mu = mu;
    }

    fn save_state(&self, w: &mut crate::snapshot::SnapWriter) -> anyhow::Result<()> {
        w.put_str(self.name());
        w.put_mat(&self.b);
        w.put_f64(self.mu);
        w.put_u64(self.samples);
        w.put_usize(self.p_idx);
        w.put_mat(&self.hsum);
        Ok(())
    }

    fn load_state(&mut self, r: &mut crate::snapshot::SnapReader<'_>) -> anyhow::Result<()> {
        crate::snapshot::expect_tag(r, self.name())?;
        let b: Mat<T> = r.get_mat()?;
        anyhow::ensure!(
            b.shape() == self.b.shape(),
            "snapshot B is {:?}, session expects {:?}",
            b.shape(),
            self.b.shape()
        );
        self.b = b;
        self.mu = r.get_f64()?;
        self.samples = r.get_u64()?;
        self.p_idx = r.get_usize()?;
        anyhow::ensure!(
            self.p_idx < self.p,
            "snapshot batch position {} is outside P = {}",
            self.p_idx,
            self.p
        );
        let hsum: Mat<T> = r.get_mat()?;
        anyhow::ensure!(hsum.shape() == self.hsum.shape(), "snapshot accumulator shape mismatch");
        self.hsum = hsum;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ica::EasiSgd;
    use crate::linalg::Mat64;
    use crate::signal::{Dataset, Pcg32};

    #[test]
    fn p1_equals_sgd() {
        let mut rng = Pcg32::seed(1);
        let b0 = Mat64::from_fn(2, 4, |_, _| rng.normal() * 0.3);
        let mut mbgd = Mbgd::new(b0.clone(), 0.004, 1, Nonlinearity::Cube);
        let mut sgd = EasiSgd::new(b0, 0.004, Nonlinearity::Cube);
        for _ in 0..100 {
            let x: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
            mbgd.step(&x);
            sgd.step(&x);
        }
        assert!(mbgd.b().max_abs_diff(sgd.b()) < 1e-12);
    }

    #[test]
    fn update_is_batch_average() {
        let mut rng = Pcg32::seed(2);
        let b0 = Mat64::from_fn(2, 4, |_, _| rng.normal() * 0.3);
        let xs: Vec<Vec<f64>> =
            (0..4).map(|_| (0..4).map(|_| rng.normal()).collect()).collect();
        let mu = 0.01;
        let mut opt = Mbgd::new(b0.clone(), mu, 4, Nonlinearity::Cube);
        for x in &xs {
            opt.step(x);
        }
        // Oracle: average H at stale B, single update.
        let n = 2;
        let mut y = vec![0.0; n];
        let mut gy = vec![0.0; n];
        let mut h = Mat64::zeros(n, n);
        let mut havg = Mat64::zeros(n, n);
        for x in &xs {
            EasiSgd::relative_gradient(
                &b0, x, Nonlinearity::Cube, false, mu, &mut y, &mut gy, &mut h,
            );
            havg.axpy(0.25, &h);
        }
        let mut want = b0.clone();
        want.axpy(-mu, &havg.matmul(&b0));
        assert!(opt.b().max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn b_frozen_within_batch() {
        let mut rng = Pcg32::seed(3);
        let mut opt = Mbgd::with_identity_init(2, 4, 0.01, 8, Nonlinearity::Cube);
        let before = opt.b().clone();
        for _ in 0..7 {
            let x: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
            opt.step(&x);
        }
        assert_eq!(opt.b(), &before);
    }

    #[test]
    fn separates_static_mixture() {
        let ds = Dataset::standard(11, 4, 2, 80_000);
        let std_x = {
            let s: f64 = ds.x.as_slice().iter().map(|v| v * v).sum();
            (s / ds.x.as_slice().len() as f64).sqrt()
        };
        let mut opt = Mbgd::with_identity_init(2, 4, 0.02, 8, Nonlinearity::Cube);
        let mut x = vec![0.0; 4];
        for t in 0..ds.len() {
            for (i, v) in ds.sample(t).iter().enumerate() {
                x[i] = v / std_x;
            }
            opt.step(&x);
        }
        let c = opt.b().matmul(&ds.a);
        let amari = super::super::metrics::amari_index(&c);
        assert!(amari < 0.2, "amari {amari}");
    }
}
