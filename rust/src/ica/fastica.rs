//! FastICA — the nonadaptive baseline (§II, §III).
//!
//! Symmetric (parallel) FastICA with the kurtosis contrast `g(u) = u³`
//! on explicitly whitened data: fixed-point iteration
//!
//! ```text
//!   W⁺ᵢ = E[z g(wᵢᵀz)] − E[g'(wᵢᵀz)] wᵢ        (one Newton-like step)
//!   W   = (W⁺ W⁺ᵀ)^{−1/2} W⁺                    (symmetric decorrelation)
//! ```
//!
//! The paper contrasts EASI against FastICA on exactly one axis:
//! FastICA converges in far fewer *batch* iterations but cannot track
//! time-varying mixing (it needs the whole batch up front). The
//! adaptive-tracking bench (A3) demonstrates this.

use super::whiten::Whitener;
use crate::linalg::{jacobi_eig, Mat64};
use anyhow::{bail, Context, Result};
use crate::signal::Pcg32;

/// FastICA result.
pub struct FastIcaResult {
    /// Combined separation matrix (n × m): `y = B x` (includes whitening).
    pub b: Mat64,
    /// Rotation on whitened data (n × n).
    pub w: Mat64,
    /// Fixed-point iterations used.
    pub iterations: usize,
    /// Final convergence delta (1 − min |diag(WₖWₖ₋₁ᵀ)|).
    pub delta: f64,
}

/// Configuration for [`fastica`].
#[derive(Clone, Copy, Debug)]
pub struct FastIcaParams {
    pub max_iters: usize,
    /// Convergence tolerance on the rotation delta.
    pub tol: f64,
    pub seed: u64,
}

impl Default for FastIcaParams {
    fn default() -> Self {
        Self { max_iters: 200, tol: 1e-6, seed: 0xFA57 }
    }
}

/// Run symmetric FastICA on observations `x` (T × m), extracting `n`
/// components.
pub fn fastica(x: &Mat64, n: usize, params: FastIcaParams) -> Result<FastIcaResult> {
    let (t, _m) = x.shape();
    let whitener = Whitener::fit(x, n).context("fastica whitening")?;
    let z = whitener.transform(x); // T × n

    // Random orthonormal init.
    let mut rng = Pcg32::seed(params.seed);
    let mut w = random_orthonormal(&mut rng, n);

    let mut iterations = 0;
    let mut delta = f64::INFINITY;
    for it in 0..params.max_iters {
        iterations = it + 1;
        let w_old = w.clone();

        // One fixed-point step for all rows in parallel.
        // u = Z wᵀ (T × n); g(u) = u³; g'(u) = 3u².
        let mut w_plus = Mat64::zeros(n, n);
        for comp in 0..n {
            let wrow = w.row(comp).to_vec();
            let mut e_zg = vec![0.0; n];
            let mut e_gp = 0.0;
            for i in 0..t {
                let zi = z.row(i);
                let mut u = 0.0;
                for j in 0..n {
                    u += wrow[j] * zi[j];
                }
                let gu = u * u * u;
                e_gp += 3.0 * u * u;
                for j in 0..n {
                    e_zg[j] += zi[j] * gu;
                }
            }
            let tf = t as f64;
            e_gp /= tf;
            for j in 0..n {
                w_plus[(comp, j)] = e_zg[j] / tf - e_gp * wrow[j];
            }
        }

        // Symmetric decorrelation: W ← (W⁺W⁺ᵀ)^{−1/2} W⁺.
        w = symmetric_decorrelate(&w_plus)?;

        // Convergence: every component direction stationary up to sign.
        let overlap = w.matmul(&w_old.transpose());
        delta = (0..n)
            .map(|i| 1.0 - overlap[(i, i)].abs())
            .fold(0.0f64, f64::max);
        if delta < params.tol {
            break;
        }
    }

    let b = w.matmul(&whitener.w);
    Ok(FastIcaResult { b, w, iterations, delta })
}

/// `(M Mᵀ)^{−1/2} M` via Jacobi eigendecomposition of the Gram matrix.
fn symmetric_decorrelate(m: &Mat64) -> Result<Mat64> {
    let gram = m.matmul(&m.transpose());
    let eig = jacobi_eig(&gram)?;
    for &ev in &eig.values {
        if ev <= 1e-15 {
            bail!("symmetric decorrelation: rank-deficient update");
        }
    }
    let n = m.rows();
    // (E D^{-1/2} Eᵀ) M
    let mut d = Mat64::zeros(n, n);
    for i in 0..n {
        d[(i, i)] = 1.0 / eig.values[i].sqrt();
    }
    Ok(eig
        .vectors
        .matmul(&d)
        .matmul(&eig.vectors.transpose())
        .matmul(m))
}

/// Random orthonormal n × n matrix (Gram-Schmidt on Gaussian rows).
fn random_orthonormal(rng: &mut Pcg32, n: usize) -> Mat64 {
    let mut w = Mat64::zeros(n, n);
    for i in 0..n {
        loop {
            let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            // Project out previous rows.
            for prev in 0..i {
                let dot: f64 = (0..n).map(|j| v[j] * w[(prev, j)]).sum();
                for j in 0..n {
                    v[j] -= dot * w[(prev, j)];
                }
            }
            let norm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 1e-6 {
                for j in 0..n {
                    w[(i, j)] = v[j] / norm;
                }
                break;
            }
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ica::metrics::amari_index;
    use crate::signal::Dataset;

    #[test]
    fn separates_static_mixture() {
        let ds = Dataset::standard(21, 4, 2, 20_000);
        let res = fastica(&ds.x, 2, FastIcaParams::default()).unwrap();
        let c = res.b.matmul(&ds.a);
        let amari = amari_index(&c);
        assert!(amari < 0.05, "fastica amari {amari}");
    }

    #[test]
    fn converges_in_few_iterations() {
        // The nonadaptive advantage the paper concedes (§III): FastICA
        // needs orders of magnitude fewer iterations than adaptive EASI.
        let ds = Dataset::standard(22, 4, 2, 20_000);
        let res = fastica(&ds.x, 2, FastIcaParams::default()).unwrap();
        assert!(
            res.iterations < 50,
            "fastica should converge fast, took {}",
            res.iterations
        );
        assert!(res.delta < 1e-6);
    }

    #[test]
    fn rotation_is_orthonormal() {
        let ds = Dataset::standard(23, 4, 2, 10_000);
        let res = fastica(&ds.x, 2, FastIcaParams::default()).unwrap();
        let wwt = res.w.matmul(&res.w.transpose());
        assert!(wwt.max_abs_diff(&Mat64::eye(2, 2)) < 1e-8);
    }

    #[test]
    fn full_rank_separation() {
        let ds = Dataset::standard(24, 4, 4, 40_000);
        let res = fastica(&ds.x, 4, FastIcaParams::default()).unwrap();
        let c = res.b.matmul(&ds.a);
        let amari = amari_index(&c);
        assert!(amari < 0.1, "4x4 fastica amari {amari}");
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = Dataset::standard(25, 4, 2, 5_000);
        let a = fastica(&ds.x, 2, FastIcaParams::default()).unwrap();
        let b = fastica(&ds.x, 2, FastIcaParams::default()).unwrap();
        assert!(a.b.max_abs_diff(&b.b) < 1e-15);
    }

    #[test]
    fn random_orthonormal_is_orthonormal() {
        let mut rng = Pcg32::seed(1);
        for n in 1..6 {
            let w = random_orthonormal(&mut rng, n);
            let wwt = w.matmul(&w.transpose());
            assert!(wwt.max_abs_diff(&Mat64::eye(n, n)) < 1e-12);
        }
    }
}
