//! Nonlinearities g(·) for the EASI relative gradient.
//!
//! The nonlinearity introduces the higher-order statistics (§III): EASI's
//! stationary points require `E[g(yᵢ)yⱼ] = 0` for i ≠ j, which only
//! constrains independence when g is nonlinear. Stability of a source pair
//! (i, j) requires `κᵢ + κⱼ > 0` with `κᵢ = E[g'(sᵢ)] − E[sᵢ g(sᵢ)]`
//! (Cardoso & Laheld, Thm. 2):
//!
//! - **Cube** (`g(y)=y³`, the paper's choice): κ = −kurtosis, so cubic
//!   EASI separates *sub*-Gaussian source pairs. Hardware cost: 2 multiplies.
//! - **Tanh** (previous implementations [12][13]): separates
//!   *super*-Gaussian pairs; expensive on FPGA (the paper's motivation for
//!   the cubic).
//! - **SignedSquare** (`g(y)=y·|y|`): a cheaper odd nonlinearity in the
//!   same family as tanh-like rules (1 multiply + sign logic). The "ReLU-
//!   class" simplification the paper's §V.B suggests exploring — what a
//!   ReLU-style unit computes once oddness (required for EASI's
//!   antisymmetric term) is restored.

use crate::linalg::Scalar;

/// Elementwise nonlinearity used in the relative-gradient computation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Nonlinearity {
    /// `g(y) = y³` — the paper's pick; separates sub-Gaussian pairs.
    Cube,
    /// `g(y) = tanh(y)` — classic; separates super-Gaussian pairs.
    Tanh,
    /// `g(y) = y·|y|` — cheap odd square; separates sub-Gaussian pairs
    /// (same sign convention as Cube, weaker HOS weighting).
    SignedSquare,
}

impl Nonlinearity {
    /// Apply g elementwise (generic over the request path's [`Scalar`]
    /// precision — the paper's hardware evaluates g in 32-bit float).
    #[inline(always)]
    pub fn apply<T: Scalar>(self, y: T) -> T {
        match self {
            Self::Cube => y * y * y,
            Self::Tanh => y.tanh(),
            Self::SignedSquare => y * y.abs(),
        }
    }

    /// Apply g to a slice, writing into `out`.
    #[inline]
    pub fn apply_slice<T: Scalar>(self, y: &[T], out: &mut [T]) {
        debug_assert_eq!(y.len(), out.len());
        match self {
            // Monomorphized loops: keeps the hot path free of per-element
            // match dispatch (measured in EXPERIMENTS.md §Perf).
            Self::Cube => {
                for (o, &v) in out.iter_mut().zip(y) {
                    *o = v * v * v;
                }
            }
            Self::Tanh => {
                for (o, &v) in out.iter_mut().zip(y) {
                    *o = v.tanh();
                }
            }
            Self::SignedSquare => {
                for (o, &v) in out.iter_mut().zip(y) {
                    *o = v * v.abs();
                }
            }
        }
    }

    /// κ for a unit-variance source with the given excess kurtosis —
    /// `κᵢ + κⱼ > 0` is the pairwise stability condition. Exact for Cube;
    /// a same-sign proxy for the others (used only for diagnostics).
    pub fn stability_kappa(self, excess_kurtosis: f64) -> f64 {
        match self {
            Self::Cube => -excess_kurtosis,
            // tanh: κ > 0 for super-Gaussian sources.
            Self::Tanh => excess_kurtosis,
            Self::SignedSquare => -excess_kurtosis,
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "cube" => Self::Cube,
            "tanh" => Self::Tanh,
            "signed_square" => Self::SignedSquare,
            other => anyhow::bail!("unknown nonlinearity '{other}'"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Cube => "cube",
            Self::Tanh => "tanh",
            Self::SignedSquare => "signed_square",
        }
    }

    /// FP operation count per element (add, mul, other) — consumed by the
    /// FPGA resource model (`fpga::resources`) for the nonlinearity
    /// ablation (paper §V.B: the nonlinearity affects ALMs/DSPs, not Fmax).
    pub fn op_costs(self) -> (usize, usize, usize) {
        match self {
            Self::Cube => (0, 2, 0),
            // tanh on FPGA: piecewise/CORDIC ≈ 8 add + 8 mul equivalents.
            Self::Tanh => (8, 8, 1),
            Self::SignedSquare => (0, 1, 1),
        }
    }
}

/// Dispatch a runtime [`Nonlinearity`] to a *monomorphized* closure bound
/// as `$gf` over scalar type `$t`, then evaluate `$body` once: the fused
/// `linalg` kernels are generic over `Fn(T) -> T`, so each arm compiles
/// its own branch-free inner loop per precision and the match happens once
/// per kernel call, not per element (the same trick `apply_slice` uses,
/// lifted to whole kernels).
///
/// ```ignore
/// with_g!(T, self.g, gf => fused::relative_gradient_step_into(b, x, gf, mu, s));
/// ```
macro_rules! with_g {
    ($t:ty, $g:expr, $gf:ident => $body:expr) => {
        match $g {
            $crate::ica::Nonlinearity::Cube => {
                let $gf = |v: $t| v * v * v;
                $body
            }
            $crate::ica::Nonlinearity::Tanh => {
                let $gf = |v: $t| <$t as $crate::linalg::Scalar>::tanh(v);
                $body
            }
            $crate::ica::Nonlinearity::SignedSquare => {
                let $gf = |v: $t| v * <$t as $crate::linalg::Scalar>::abs(v);
                $body
            }
        }
    };
}
pub(crate) use with_g;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cube_values() {
        assert_eq!(Nonlinearity::Cube.apply(2.0), 8.0);
        assert_eq!(Nonlinearity::Cube.apply(-2.0), -8.0);
    }

    #[test]
    fn all_are_odd_functions() {
        for g in [Nonlinearity::Cube, Nonlinearity::Tanh, Nonlinearity::SignedSquare] {
            for &y in &[0.1, 0.7, 1.3, 2.9] {
                let pos = g.apply(y);
                let neg = g.apply(-y);
                assert!(
                    (pos + neg).abs() < 1e-12,
                    "{:?} not odd at {y}",
                    g
                );
            }
        }
    }

    #[test]
    fn macro_dispatch_matches_apply_bitwise() {
        // The with_g! closures feed the fused kernels; they must agree
        // with apply()/apply_slice() to the bit or the fused path drifts.
        for g in [Nonlinearity::Cube, Nonlinearity::Tanh, Nonlinearity::SignedSquare] {
            for &y in &[0.3f64, -1.2, 2.0, -0.0] {
                let via_macro = with_g!(f64, g, gf => gf(y));
                assert_eq!(via_macro.to_bits(), g.apply(y).to_bits());
            }
        }
    }

    #[test]
    fn f32_macro_dispatch_matches_generic_apply_bitwise() {
        // The same contract at the paper's 32-bit precision: the f32
        // closures the optimizers feed the fused kernels must match the
        // generic apply::<f32>() to the bit.
        for g in [Nonlinearity::Cube, Nonlinearity::Tanh, Nonlinearity::SignedSquare] {
            for &y in &[0.3f32, -1.2, 2.0, -0.0] {
                let via_macro = with_g!(f32, g, gf => gf(y));
                assert_eq!(via_macro.to_bits(), g.apply(y).to_bits());
            }
        }
    }

    #[test]
    fn apply_slice_matches_scalar() {
        let y = [0.5, -1.0, 2.0, -0.25];
        let mut out = [0.0; 4];
        for g in [Nonlinearity::Cube, Nonlinearity::Tanh, Nonlinearity::SignedSquare] {
            g.apply_slice(&y, &mut out);
            for i in 0..4 {
                assert_eq!(out[i], g.apply(y[i]));
            }
        }
    }

    #[test]
    fn cube_stability_favors_sub_gaussian() {
        let g = Nonlinearity::Cube;
        assert!(g.stability_kappa(-1.2) > 0.0, "uniform should be stable");
        assert!(g.stability_kappa(3.0) < 0.0, "laplace should be unstable");
    }

    #[test]
    fn tanh_stability_favors_super_gaussian() {
        let g = Nonlinearity::Tanh;
        assert!(g.stability_kappa(3.0) > 0.0);
        assert!(g.stability_kappa(-1.2) < 0.0);
    }

    #[test]
    fn parse_round_trip() {
        for g in [Nonlinearity::Cube, Nonlinearity::Tanh, Nonlinearity::SignedSquare] {
            assert_eq!(Nonlinearity::parse(g.name()).unwrap(), g);
        }
        assert!(Nonlinearity::parse("relu6").is_err());
    }

    #[test]
    fn cube_is_cheapest_multiplier_user() {
        let (_, cube_mul, _) = Nonlinearity::Cube.op_costs();
        let (_, tanh_mul, _) = Nonlinearity::Tanh.op_costs();
        assert!(cube_mul < tanh_mul, "paper: cubic is cheaper than tanh");
    }
}
