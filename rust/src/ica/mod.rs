//! ICA algorithm library: EASI (SGD / SMBGD / MBGD), the FastICA
//! baseline, whitening, nonlinearities, metrics, and convergence drivers.
//!
//! This is the native-Rust implementation of the paper's algorithm family
//! (the PJRT engine in `runtime`/`coordinator` executes the same math from
//! AOT-compiled JAX/Pallas artifacts; parity tests pin the two together).
//!
//! The central abstraction is [`Optimizer`]: a streaming separation-matrix
//! learner fed one sample at a time — exactly the interface the paper's
//! hardware exposes (one sample per clock into the pipeline).

pub mod convergence;
pub mod easi;
pub mod fastica;
pub mod mbgd;
pub mod metrics;
pub mod nonlinearity;
pub mod quant;
pub mod schedule;
pub mod smbgd;
pub mod whiten;

pub use convergence::{
    run_to_convergence, ConvergenceCriterion, ConvergenceReport, ConvergenceStudy,
};
pub use easi::EasiSgd;
pub use fastica::{fastica, FastIcaParams, FastIcaResult};
pub use mbgd::Mbgd;
pub use metrics::{amari_index, isi, matched_abs_correlation, sir_db};
pub use nonlinearity::Nonlinearity;
pub use quant::{QFormat, QuantizedEasi};
pub use schedule::{MuSchedule, ScheduledSgd};
pub use smbgd::{Smbgd, SmbgdParams};
pub use whiten::Whitener;

use crate::config::{OptimizerConfig, OptimizerKind};
use crate::linalg::{Mat, Mat64, Scalar};

/// A streaming separation-matrix learner (the paper's training datapath),
/// generic over the request path's [`Scalar`] precision.
///
/// One `step` consumes one observation sample `x` (length m). The current
/// estimate is `b()` (n × m); estimated components are `y = B x`.
/// `Optimizer` without type arguments means `Optimizer<f64>` — the
/// bit-exact default every existing caller gets; `Optimizer<f32>` is the
/// paper's 32-bit datapath precision, built via [`make_optimizer_t`].
pub trait Optimizer<T: Scalar = f64>: Send {
    /// Consume one sample, possibly updating the separation matrix.
    fn step(&mut self, x: &[T]);
    /// Current separation matrix (n × m).
    fn b(&self) -> &Mat<T>;
    /// Mutable access (used by the coordinator to install snapshots).
    fn b_mut(&mut self) -> &mut Mat<T>;
    /// Total samples consumed.
    fn samples_seen(&self) -> u64;
    /// Optimizer name for reports.
    fn name(&self) -> &'static str;

    /// Install a new learning rate μ (the adaptive control plane's knob —
    /// `coordinator::Engine::set_mu` forwards here). Default: no-op, for
    /// optimizers whose rate is not externally governable (e.g.
    /// [`ScheduledSgd`], whose schedule owns μ).
    fn set_mu(&mut self, _mu: f64) {}

    /// Feed a whole row-major batch (default: loop over rows).
    fn step_batch(&mut self, xs: &Mat<T>) {
        for t in 0..xs.rows() {
            self.step(xs.row(t));
        }
    }

    /// Cohort-execution probe: `Some((μ, g))` iff this optimizer's `step`
    /// is *exactly* the plain (non-normalized) fused EASI-SGD kernel, so
    /// a tenant-major [`crate::linalg::CohortState`] lane loaded with
    /// `(b(), μ)` reproduces it bit-for-bit. Everything else (normalized
    /// EASI, the mini-batch family, schedules) returns `None` and keeps
    /// the per-session path. Default: `None`.
    fn cohort_plain(&self) -> Option<(f64, Nonlinearity)> {
        None
    }

    /// Bookkeeping after a cohort kernel advanced this optimizer's `B`
    /// externally (via `b_mut`): account the `rows` samples it consumed.
    /// Only called on optimizers that returned `Some` from
    /// [`cohort_plain`](Self::cohort_plain); default is a no-op.
    fn note_cohort_rows(&mut self, _rows: u64) {}

    /// Cohort-execution probe for the mini-batch family: `Some((params,
    /// g))` iff this optimizer is *exactly* the plain SMBGD form at a
    /// batch boundary, so a [`crate::linalg::CohortSmbgdState`] lane
    /// loaded with `(b(), Ĥ_prev, μ, γ, β)` reproduces its fused block
    /// path bit-for-bit. Mid-batch state (`p_idx != 0`) must return
    /// `None` — the cohort kernel only steps whole mini-batches. Default:
    /// `None` (everything that isn't plain SMBGD keeps the solo path).
    fn cohort_smbgd(&self) -> Option<(SmbgdParams, Nonlinearity)> {
        None
    }

    /// The cross-batch accumulator `Ĥ_prev` in the f64 wire format, for
    /// loading into an SMBGD cohort lane. Only called on optimizers that
    /// returned `Some` from [`cohort_smbgd`](Self::cohort_smbgd).
    fn cohort_hhat_prev(&self) -> Mat64 {
        unreachable!("cohort_hhat_prev on '{}' (not SMBGD-cohort-eligible)", self.name())
    }

    /// Install the state an SMBGD cohort step produced for this lane:
    /// `B`, the latched `Ĥ_prev` (which is also the post-latch `Ĥ` — the
    /// solo invariant at every batch boundary), and account `rows`
    /// samples / `rows / P` completed mini-batches. Only called on
    /// optimizers that returned `Some` from
    /// [`cohort_smbgd`](Self::cohort_smbgd).
    fn cohort_sync_smbgd(&mut self, _b: &Mat64, _hhat_prev: &Mat64, _rows: u64) {
        unreachable!("cohort_sync_smbgd on '{}' (not SMBGD-cohort-eligible)", self.name())
    }

    /// Serialize the optimizer's full learning state (matrix, rate,
    /// accumulators, sample clock) into a detach-to-disk snapshot. The
    /// format is a contract with [`load_state`](Self::load_state): a
    /// restored optimizer continues **bit-identically**. Default: error —
    /// optimizers that never grew a snapshot story (schedules, quantized
    /// wrappers) refuse instead of silently persisting half their state.
    fn save_state(&self, _w: &mut crate::snapshot::SnapWriter) -> anyhow::Result<()> {
        anyhow::bail!("optimizer '{}' does not support state snapshots", self.name())
    }

    /// Rehydrate the state written by [`save_state`](Self::save_state).
    /// The optimizer must already be constructed with the same config
    /// (kind, shape, nonlinearity); this installs the learned state.
    fn load_state(&mut self, _r: &mut crate::snapshot::SnapReader<'_>) -> anyhow::Result<()> {
        anyhow::bail!("optimizer '{}' does not support state snapshots", self.name())
    }
}

/// Build an optimizer from an [`OptimizerConfig`] with an identity-like
/// warm start (`B₀ = 0.5·[I 0]`) — the `f64` request path.
pub fn make_optimizer(
    cfg: &OptimizerConfig,
    n: usize,
    m: usize,
    g: Nonlinearity,
) -> Box<dyn Optimizer> {
    make_optimizer_with_init(cfg, init_b(n, m), g)
}

/// Build an optimizer from a config with an explicit initial matrix
/// (`f64` request path).
pub fn make_optimizer_with_init(
    cfg: &OptimizerConfig,
    b0: Mat64,
    g: Nonlinearity,
) -> Box<dyn Optimizer> {
    make_optimizer_with_init_t::<f64>(cfg, b0, g)
}

/// Precision-generic factory: build an optimizer running entirely in `T`
/// with the identity-like warm start. The coordinator uses
/// `make_optimizer_t::<f32>` for `precision = "f32"` tenants.
pub fn make_optimizer_t<T: Scalar>(
    cfg: &OptimizerConfig,
    n: usize,
    m: usize,
    g: Nonlinearity,
) -> Box<dyn Optimizer<T>> {
    make_optimizer_with_init_t(cfg, init_b_t::<T>(n, m), g)
}

/// Precision-generic factory with an explicit initial matrix.
pub fn make_optimizer_with_init_t<T: Scalar>(
    cfg: &OptimizerConfig,
    b0: Mat<T>,
    g: Nonlinearity,
) -> Box<dyn Optimizer<T>> {
    match cfg.kind {
        OptimizerKind::Sgd => Box::new(EasiSgd::new(b0, cfg.mu, g)),
        OptimizerKind::Smbgd => Box::new(Smbgd::new(
            b0,
            SmbgdParams { mu: cfg.mu, gamma: cfg.gamma, beta: cfg.beta, p: cfg.p },
            g,
        )),
        OptimizerKind::Mbgd => Box::new(Mbgd::new(b0, cfg.mu, cfg.p, g)),
    }
}

/// The standard identity-like warm start `B₀ = 0.5·[I 0]` (n × m).
pub fn init_b(n: usize, m: usize) -> Mat64 {
    init_b_t::<f64>(n, m)
}

/// Precision-generic identity-like warm start. `0.5` is exactly
/// representable in every binary float, so `init_b_t::<f32>` is the
/// narrowed image of [`init_b`] bit-for-bit.
pub fn init_b_t<T: Scalar>(n: usize, m: usize) -> Mat<T> {
    let mut b = Mat::<T>::eye(n, m);
    b.scale(T::scalar_from_f64(0.5));
    b
}

/// A randomized full-rank initial matrix for the multi-seed convergence
/// study (E1): identity-like plus scaled Gaussian perturbation.
pub fn random_init_b(rng: &mut crate::signal::Pcg32, n: usize, m: usize) -> Mat64 {
    let mut b = Mat64::from_fn(n, m, |i, j| {
        let base = if i == j { 0.5 } else { 0.0 };
        base + 0.2 * rng.normal()
    });
    // Reject near-singular draws (full row rank needed for separation).
    while {
        let g = b.matmul(&b.transpose());
        crate::linalg::jacobi_eig(&g)
            .map(|e| e.values.last().copied().unwrap_or(0.0) < 1e-3)
            .unwrap_or(true)
    } {
        b = Mat64::from_fn(n, m, |i, j| {
            let base = if i == j { 0.5 } else { 0.0 };
            base + 0.2 * rng.normal()
        });
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OptimizerConfig;
    use crate::signal::Pcg32;

    #[test]
    fn factory_builds_all_kinds() {
        for kind in [OptimizerKind::Sgd, OptimizerKind::Smbgd, OptimizerKind::Mbgd] {
            let cfg = OptimizerConfig { kind, ..Default::default() };
            let opt = make_optimizer(&cfg, 2, 4, Nonlinearity::Cube);
            assert_eq!(opt.b().shape(), (2, 4));
            assert_eq!(opt.samples_seen(), 0);
        }
    }

    #[test]
    fn step_batch_equals_loop() {
        let cfg = OptimizerConfig::default();
        let mut rng = Pcg32::seed(1);
        let xs = Mat64::from_fn(32, 4, |_, _| rng.normal());
        let mut a = make_optimizer(&cfg, 2, 4, Nonlinearity::Cube);
        let mut b = make_optimizer(&cfg, 2, 4, Nonlinearity::Cube);
        a.step_batch(&xs);
        for t in 0..xs.rows() {
            b.step(xs.row(t));
        }
        assert!(a.b().max_abs_diff(b.b()) < 1e-15);
    }

    #[test]
    fn random_init_is_full_rank() {
        let mut rng = Pcg32::seed(2);
        for _ in 0..50 {
            let b = random_init_b(&mut rng, 2, 4);
            let g = b.matmul(&b.transpose());
            let e = crate::linalg::jacobi_eig(&g).unwrap();
            assert!(e.values[1] >= 1e-3);
        }
    }
}
