//! Learning-rate schedules — the "variable learning rate" of Odom [12].
//!
//! Adaptive ICA with a constant μ trades steady-state accuracy against
//! tracking speed: large μ converges fast but jitters around the solution;
//! small μ settles low but converges (and re-tracks) slowly. A decaying
//! schedule gets both on stationary problems, while constant μ is what a
//! *tracking* deployment wants (the paper targets non-stationary inputs,
//! which is why its hardware bakes μ in as a constant-coefficient
//! multiplier). The A5 ablation (`cargo bench --bench ablation_schedule`)
//! quantifies the trade-off.

use super::Optimizer;
use crate::linalg::{Mat, Scalar};

/// A learning-rate schedule μ(t).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MuSchedule {
    /// μ(t) = μ₀ — what the paper's hardware implements.
    Constant { mu0: f64 },
    /// μ(t) = μ₀ / (1 + t/τ) — the classic Robbins–Monro-style decay.
    InverseDecay { mu0: f64, tau: f64 },
    /// μ(t) = μ₀ · factor^⌊t/every⌋ — staircase decay (cheap in hardware:
    /// a coefficient-bank switch, which is how [12] realizes it).
    Step { mu0: f64, factor: f64, every: u64 },
    /// Decay to a floor: max(μ₀/(1+t/τ), floor) — keeps residual
    /// adaptivity for tracking after settling.
    DecayToFloor { mu0: f64, tau: f64, floor: f64 },
    /// Closed-loop schedule (PR 4 — the adaptive control plane): anneal
    /// like `DecayToFloor`, but **boost** μ to `boost·μ₀` when the drift
    /// detector fires (restarting the anneal clock) and scale the floor
    /// inversely with the tracked fourth moment of the outputs (Gültekin
    /// et al.). `mu_at` evaluates only the *open-loop envelope*
    /// `max(μ₀/(1+t/τ), floor_min)` — the boost and moment floor need
    /// runtime state, which lives in [`crate::adapt::Governor`]; drive it
    /// through [`crate::adapt::AdaptiveSgd`] or the coordinator's
    /// `adapt.enabled` config, not [`ScheduledSgd`].
    Adaptive { mu0: f64, boost: f64, tau: f64, floor_min: f64 },
}

impl MuSchedule {
    /// Learning rate at sample index `t`.
    pub fn mu_at(&self, t: u64) -> f64 {
        match *self {
            Self::Constant { mu0 } => mu0,
            Self::InverseDecay { mu0, tau } => mu0 / (1.0 + t as f64 / tau),
            Self::Step { mu0, factor, every } => {
                mu0 * factor.powi((t / every.max(1)) as i32)
            }
            Self::DecayToFloor { mu0, tau, floor } => {
                (mu0 / (1.0 + t as f64 / tau)).max(floor)
            }
            // Open-loop envelope only; see the variant docs.
            Self::Adaptive { mu0, tau, floor_min, .. } => {
                (mu0 / (1.0 + t as f64 / tau)).max(floor_min)
            }
        }
    }

    /// Validate parameters (panics on nonsense — schedules are
    /// compile-time experiment configuration).
    pub fn validate(&self) {
        let ok = match *self {
            Self::Constant { mu0 } => mu0 > 0.0,
            Self::InverseDecay { mu0, tau } => mu0 > 0.0 && tau > 0.0,
            Self::Step { mu0, factor, every } => {
                mu0 > 0.0 && (0.0..=1.0).contains(&factor) && every > 0
            }
            Self::DecayToFloor { mu0, tau, floor } => {
                mu0 > 0.0 && tau > 0.0 && floor > 0.0 && floor <= mu0
            }
            Self::Adaptive { mu0, boost, tau, floor_min } => {
                mu0 > 0.0 && boost >= 1.0 && tau > 0.0 && floor_min > 0.0 && floor_min <= mu0
            }
        };
        assert!(ok, "invalid schedule {self:?}");
    }
}

/// Wrap any μ-settable optimizer with a schedule.
///
/// Works with [`super::EasiSgd`] (the only optimizer whose per-sample μ is
/// well-defined; SMBGD's μ interacts with β/γ so scheduling it is a
/// different algorithm — see module docs). Generic over the request
/// path's [`Scalar`] precision like the optimizer it wraps; the schedule
/// itself always evaluates μ(t) in `f64` (hyperparameter space).
pub struct ScheduledSgd<T: Scalar = f64> {
    inner: super::EasiSgd<T>,
    schedule: MuSchedule,
}

impl<T: Scalar> ScheduledSgd<T> {
    pub fn new(inner: super::EasiSgd<T>, schedule: MuSchedule) -> Self {
        schedule.validate();
        assert!(
            !matches!(schedule, MuSchedule::Adaptive { .. }),
            "MuSchedule::Adaptive is closed-loop; drive it through adapt::AdaptiveSgd \
             or the coordinator's adapt.enabled config"
        );
        Self { inner, schedule }
    }

    pub fn schedule(&self) -> MuSchedule {
        self.schedule
    }

    pub fn current_mu(&self) -> f64 {
        self.schedule.mu_at(self.inner.samples_seen())
    }
}

impl<T: Scalar> Optimizer<T> for ScheduledSgd<T> {
    fn step(&mut self, x: &[T]) {
        let mu = self.schedule.mu_at(self.inner.samples_seen());
        self.inner.set_mu(mu);
        self.inner.step(x);
    }

    fn b(&self) -> &Mat<T> {
        self.inner.b()
    }

    fn b_mut(&mut self) -> &mut Mat<T> {
        self.inner.b_mut()
    }

    fn samples_seen(&self) -> u64 {
        self.inner.samples_seen()
    }

    fn name(&self) -> &'static str {
        "easi-sgd-scheduled"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ica::{amari_index, EasiSgd, Nonlinearity};
    use crate::signal::Dataset;

    #[test]
    fn schedules_evaluate() {
        let c = MuSchedule::Constant { mu0: 0.01 };
        assert_eq!(c.mu_at(0), 0.01);
        assert_eq!(c.mu_at(1_000_000), 0.01);

        let d = MuSchedule::InverseDecay { mu0: 0.01, tau: 100.0 };
        assert_eq!(d.mu_at(0), 0.01);
        assert!((d.mu_at(100) - 0.005).abs() < 1e-12);

        let s = MuSchedule::Step { mu0: 0.01, factor: 0.5, every: 10 };
        assert_eq!(s.mu_at(9), 0.01);
        assert_eq!(s.mu_at(10), 0.005);
        assert_eq!(s.mu_at(25), 0.0025);

        let f = MuSchedule::DecayToFloor { mu0: 0.01, tau: 10.0, floor: 0.002 };
        assert!(f.mu_at(1_000_000) >= 0.002);
    }

    #[test]
    #[should_panic(expected = "invalid schedule")]
    fn bad_schedule_rejected() {
        MuSchedule::DecayToFloor { mu0: 0.001, tau: 10.0, floor: 0.01 }.validate();
    }

    #[test]
    fn adaptive_envelope_is_decay_to_floor() {
        // Open-loop, mu_at of Adaptive equals DecayToFloor at floor_min
        // (the boost/moment terms are runtime state in adapt::Governor).
        let a = MuSchedule::Adaptive { mu0: 0.01, boost: 2.0, tau: 100.0, floor_min: 0.002 };
        a.validate();
        let d = MuSchedule::DecayToFloor { mu0: 0.01, tau: 100.0, floor: 0.002 };
        for t in [0u64, 50, 100, 10_000, 1_000_000] {
            assert_eq!(a.mu_at(t), d.mu_at(t));
        }
    }

    #[test]
    #[should_panic(expected = "invalid schedule")]
    fn adaptive_bad_boost_rejected() {
        MuSchedule::Adaptive { mu0: 0.01, boost: 0.5, tau: 100.0, floor_min: 0.002 }.validate();
    }

    #[test]
    #[should_panic(expected = "closed-loop")]
    fn scheduled_sgd_rejects_adaptive() {
        let _ = ScheduledSgd::new(
            EasiSgd::with_identity_init(2, 4, 0.01, Nonlinearity::Cube),
            MuSchedule::Adaptive { mu0: 0.01, boost: 2.0, tau: 100.0, floor_min: 0.002 },
        );
    }

    #[test]
    fn constant_schedule_equals_plain_sgd() {
        let ds = Dataset::standard(61, 4, 2, 2_000);
        let mut plain = EasiSgd::with_identity_init(2, 4, 0.005, Nonlinearity::Cube);
        let mut sched = ScheduledSgd::new(
            EasiSgd::with_identity_init(2, 4, 0.005, Nonlinearity::Cube),
            MuSchedule::Constant { mu0: 0.005 },
        );
        for t in 0..ds.len() {
            plain.step(ds.sample(t));
            sched.step(ds.sample(t));
        }
        assert!(plain.b().max_abs_diff(sched.b()) < 1e-15);
    }

    #[test]
    fn decay_reaches_lower_floor_than_constant() {
        // On a stationary problem, decayed-μ SGD settles to a lower
        // steady-state Amari than constant-μ at the same initial rate.
        let ds = Dataset::standard(62, 4, 2, 100_000);
        let pow: f64 = ds.x.as_slice().iter().map(|v| v * v).sum::<f64>()
            / ds.x.as_slice().len() as f64;
        let xs = ds.x.map(|v| v / pow.sqrt());

        let mut constant = EasiSgd::with_identity_init(2, 4, 0.01, Nonlinearity::Cube);
        let mut decayed = ScheduledSgd::new(
            EasiSgd::with_identity_init(2, 4, 0.01, Nonlinearity::Cube),
            MuSchedule::InverseDecay { mu0: 0.01, tau: 20_000.0 },
        );
        // steady-state = average of the Amari over the last 20%
        let (mut acc_c, mut acc_d, mut count) = (0.0, 0.0, 0);
        for t in 0..xs.rows() {
            constant.step(xs.row(t));
            decayed.step(xs.row(t));
            if t >= 80_000 && t % 500 == 0 {
                acc_c += amari_index(&constant.b().matmul(&ds.a));
                acc_d += amari_index(&decayed.b().matmul(&ds.a));
                count += 1;
            }
        }
        let (ss_c, ss_d) = (acc_c / count as f64, acc_d / count as f64);
        assert!(
            ss_d < ss_c,
            "decayed steady-state ({ss_d:.4}) should beat constant ({ss_c:.4})"
        );
    }
}
