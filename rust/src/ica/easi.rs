//! Vanilla EASI with per-sample SGD (paper Fig. 1) — the baseline the
//! paper's SMBGD improves on, and the native hot path of the coordinator.
//!
//! Per sample:
//! ```text
//!   y  = B x
//!   H  = y yᵀ − I + g(y) yᵀ − y g(y)ᵀ          (relative gradient [9])
//!   B ← B − μ H B                              (SGD step)
//! ```
//!
//! The optional *normalized* form (Cardoso & Laheld eq. 31) divides the
//! two gradient terms by `1 + μ yᵀy` and `1 + μ |yᵀg(y)|`, bounding the
//! step size and making large-μ operation safe; the paper's hardware uses
//! the plain form, so `normalized = false` is the default everywhere
//! results are compared against the paper.

use super::nonlinearity::{with_g, Nonlinearity};
use super::Optimizer;
use crate::linalg::{fused, FusedScratch, Mat, Scalar};

/// Per-sample EASI SGD state + scratch (allocation-free `step`).
///
/// Generic over the [`Scalar`] precision: `EasiSgd<f64>` (the default) is
/// the bit-exact reference; `EasiSgd<f32>` runs the paper's 32-bit
/// datapath precision end to end (hyperparameters stay `f64` in the
/// config space and are narrowed once per step).
pub struct EasiSgd<T: Scalar = f64> {
    b: Mat<T>,
    mu: f64,
    g: Nonlinearity,
    normalized: bool,
    samples: u64,
    // Scratch reused across steps (hot path: zero allocations).
    scratch: FusedScratch<T>,
}

impl<T: Scalar> EasiSgd<T> {
    /// Create with an explicit initial separation matrix `b0` (n × m).
    pub fn new(b0: Mat<T>, mu: f64, g: Nonlinearity) -> Self {
        let (n, m) = b0.shape();
        assert!(mu > 0.0, "mu must be positive");
        Self {
            mu,
            g,
            normalized: false,
            samples: 0,
            scratch: FusedScratch::new(n, m),
            b: b0,
        }
    }

    /// Default initialization: scaled identity-like `B₀ = c·[I 0]` — the
    /// standard EASI warm start (any full-rank B₀ works; random inits are
    /// drawn by the convergence experiments).
    pub fn with_identity_init(n: usize, m: usize, mu: f64, g: Nonlinearity) -> Self {
        let mut b0 = Mat::<T>::eye(n, m);
        b0.scale(T::scalar_from_f64(0.5));
        Self::new(b0, mu, g)
    }

    /// Enable/disable the normalized update (see module docs).
    pub fn set_normalized(&mut self, on: bool) {
        self.normalized = on;
    }

    pub fn mu(&self) -> f64 {
        self.mu
    }

    pub fn set_mu(&mut self, mu: f64) {
        assert!(mu > 0.0);
        self.mu = mu;
    }

    pub fn nonlinearity(&self) -> Nonlinearity {
        self.g
    }

    /// Compute the relative gradient H(B, x) into `h_out` using the given
    /// scratch vectors — the **unfused reference** implementation.
    ///
    /// The hot paths of [`EasiSgd`], [`super::Smbgd`] and [`super::Mbgd`]
    /// now run the fused kernels in [`crate::linalg::fused`], which are
    /// bit-identical to this form for finite data (pinned by
    /// `tests/fused_hotpath.rs`); this reference remains the oracle for
    /// those tests, the `unfused_*` baselines in the §Perf bench suite,
    /// the PJRT parity tests, and the normalized update (whose per-sample
    /// denominators are real divisions the fused plain-form kernel omits).
    pub fn relative_gradient(
        b: &Mat<T>,
        x: &[T],
        g: Nonlinearity,
        normalized: bool,
        mu: f64,
        y: &mut [T],
        gy: &mut [T],
        h_out: &mut Mat<T>,
    ) {
        b.matvec_into(x, y);
        g.apply_slice(y, gy);
        let n = y.len();
        let one = T::one();
        // Normalization denominators (1 when disabled).
        let (d1, d2) = if normalized {
            let mu_t = T::scalar_from_f64(mu);
            let yy: T = y.iter().map(|&v| v * v).sum();
            let yg: T = y.iter().zip(gy.iter()).map(|(&a, &b)| a * b).sum();
            (one + mu_t * yy, one + mu_t * yg.abs())
        } else {
            (one, one)
        };
        // H = (y yᵀ − I)/d1 + (g yᵀ − y gᵀ)/d2
        for i in 0..n {
            let yi = y[i];
            let gi = gy[i];
            let row = h_out.row_mut(i);
            for j in 0..n {
                row[j] = (yi * y[j]) / d1 + (gi * y[j] - yi * gy[j]) / d2;
            }
            row[i] -= one / d1;
        }
    }

    /// Estimated components for the current B (inference path).
    pub fn separate_into(&self, x: &[T], y_out: &mut [T]) {
        self.b.matvec_into(x, y_out);
    }
}

impl<T: Scalar> Optimizer<T> for EasiSgd<T> {
    fn step(&mut self, x: &[T]) {
        let mu_t = T::scalar_from_f64(self.mu);
        if self.normalized {
            // Normalized form: the per-sample denominators are real work,
            // so it keeps the unfused reference path.
            Self::relative_gradient(
                &self.b,
                x,
                self.g,
                true,
                self.mu,
                &mut self.scratch.y,
                &mut self.scratch.gy,
                &mut self.scratch.h,
            );
            // B ← B − μ H B
            self.scratch.h.matmul_into(&self.b, &mut self.scratch.hb);
            self.b.axpy(-mu_t, &self.scratch.hb);
        } else {
            // Plain form (the paper's hardware): the fused kernel, one
            // pass per sample — bit-identical to the sequence above with
            // `normalized = false` (pinned by tests/fused_hotpath.rs).
            let (b, s) = (&mut self.b, &mut self.scratch);
            with_g!(T, self.g, gf => fused::relative_gradient_step_into(b, x, gf, mu_t, s));
        }
        self.samples += 1;
    }

    fn b(&self) -> &Mat<T> {
        &self.b
    }

    fn b_mut(&mut self) -> &mut Mat<T> {
        &mut self.b
    }

    fn samples_seen(&self) -> u64 {
        self.samples
    }

    fn name(&self) -> &'static str {
        "easi-sgd"
    }

    fn set_mu(&mut self, mu: f64) {
        // Delegate to the inherent setter so the μ invariant lives in
        // exactly one place.
        EasiSgd::set_mu(self, mu);
    }

    fn cohort_plain(&self) -> Option<(f64, Nonlinearity)> {
        // Only the plain form is the fused kernel a cohort lane runs; the
        // normalized update has per-sample denominators the lane omits.
        if self.normalized {
            None
        } else {
            Some((self.mu, self.g))
        }
    }

    fn note_cohort_rows(&mut self, rows: u64) {
        self.samples += rows;
    }

    fn save_state(&self, w: &mut crate::snapshot::SnapWriter) -> anyhow::Result<()> {
        // g comes from config at reconstruction time; everything learned
        // or clock-like is here. The matrix widens to f64 losslessly.
        w.put_str(self.name());
        w.put_mat(&self.b);
        w.put_f64(self.mu);
        w.put_bool(self.normalized);
        w.put_u64(self.samples);
        Ok(())
    }

    fn load_state(&mut self, r: &mut crate::snapshot::SnapReader<'_>) -> anyhow::Result<()> {
        crate::snapshot::expect_tag(r, self.name())?;
        let b: Mat<T> = r.get_mat()?;
        anyhow::ensure!(
            b.shape() == self.b.shape(),
            "snapshot B is {:?}, session expects {:?}",
            b.shape(),
            self.b.shape()
        );
        self.b = b;
        self.mu = r.get_f64()?;
        self.normalized = r.get_bool()?;
        self.samples = r.get_u64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat64;
    use crate::signal::{Dataset, Pcg32};
    use crate::testkit::{check, Config};

    fn unit_rows(t: usize, m: usize, seed: u64) -> Mat64 {
        let mut rng = Pcg32::seed(seed);
        Mat64::from_fn(t, m, |_, _| rng.normal())
    }

    #[test]
    fn step_matches_manual_computation() {
        // Hand-check one update at (n,m)=(2,2).
        let b0 = Mat64::from_rows(&[&[1.0, 0.0], &[0.0, 1.0]]);
        let x = [0.5, -1.0];
        let mu = 0.01;
        let mut opt = EasiSgd::new(b0.clone(), mu, Nonlinearity::Cube);
        opt.step(&x);

        // y = x, g = y^3
        let y = [0.5, -1.0];
        let gy = [0.125, -1.0];
        let mut h = Mat64::zeros(2, 2);
        for i in 0..2 {
            for j in 0..2 {
                h[(i, j)] = y[i] * y[j] + gy[i] * y[j] - y[i] * gy[j];
            }
            h[(i, i)] -= 1.0;
        }
        let mut want = b0.clone();
        want.axpy(-mu, &h.matmul(&b0));
        assert!(opt.b().max_abs_diff(&want) < 1e-15);
    }

    #[test]
    fn gradient_vanishes_for_independent_unit_output() {
        // At a separating point with unit-variance independent outputs the
        // *expected* gradient is ~0: check the empirical mean over many
        // samples of an identity mixing with B = I.
        let mut rng = Pcg32::seed(1);
        let n = 2;
        let b = Mat64::eye(n, n);
        let mut acc = Mat64::zeros(n, n);
        let mut y = vec![0.0; n];
        let mut gy = vec![0.0; n];
        let mut h = Mat64::zeros(n, n);
        let t = 200_000;
        for _ in 0..t {
            let x = [rng.uniform_in(-1.7320508, 1.7320508), rng.rademacher()];
            EasiSgd::relative_gradient(
                &b, &x, Nonlinearity::Cube, false, 0.01, &mut y, &mut gy, &mut h,
            );
            acc.axpy(1.0 / t as f64, &h);
        }
        assert!(acc.max_abs() < 0.02, "E[H] should vanish, got {acc:?}");
    }

    #[test]
    fn separates_static_mixture() {
        let ds = Dataset::standard(3, 4, 2, 60_000);
        let std_x = {
            let mut s = 0.0;
            for v in ds.x.as_slice() {
                s += v * v;
            }
            (s / ds.x.as_slice().len() as f64).sqrt()
        };
        let mut opt = EasiSgd::with_identity_init(2, 4, 0.003, Nonlinearity::Cube);
        let mut x = vec![0.0; 4];
        for t in 0..ds.len() {
            for (i, v) in ds.sample(t).iter().enumerate() {
                x[i] = v / std_x;
            }
            opt.step(&x);
        }
        let c = opt.b().matmul(&ds.a);
        let amari = super::super::metrics::amari_index(&c);
        assert!(amari < 0.15, "amari {amari} after 60k samples");
    }

    #[test]
    fn normalized_update_is_bounded() {
        // With a huge outlier sample the plain update explodes while the
        // normalized one stays finite and small.
        let x_outlier = vec![100.0, -100.0, 100.0, -100.0];
        let mut plain = EasiSgd::with_identity_init(2, 4, 0.01, Nonlinearity::Cube);
        let mut norm = EasiSgd::with_identity_init(2, 4, 0.01, Nonlinearity::Cube);
        norm.set_normalized(true);
        plain.step(&x_outlier);
        norm.step(&x_outlier);
        assert!(plain.b().max_abs() > norm.b().max_abs());
        assert!(norm.b().max_abs() < 10.0, "normalized step should be bounded");
    }

    #[test]
    fn equivariance_of_convergence() {
        // EASI's signature property (§III): the global system C = B A
        // evolves identically for any mixing matrix A, given matched
        // initial global state. Run two different A's with B₀ = C₀ A⁻¹
        // and check the C trajectories coincide.
        let mut rng = Pcg32::seed(5);
        let n = 2;
        let a1 = crate::signal::well_conditioned_random(&mut rng, n, n, 8.0);
        let a2 = crate::signal::well_conditioned_random(&mut rng, n, n, 8.0);
        let c0 = Mat64::eye(n, n);
        let b1_0 = c0.matmul(&crate::linalg::inverse(&a1).unwrap());
        let b2_0 = c0.matmul(&crate::linalg::inverse(&a2).unwrap());
        let mut o1 = EasiSgd::new(b1_0, 0.005, Nonlinearity::Cube);
        let mut o2 = EasiSgd::new(b2_0, 0.005, Nonlinearity::Cube);
        // Identical source stream for both.
        let mut s = vec![0.0; n];
        let mut bank = crate::signal::SourceBank::sub_gaussian(n);
        for _ in 0..2000 {
            bank.next_into(&mut rng, &mut s);
            let x1 = a1.matvec(&s);
            let x2 = a2.matvec(&s);
            o1.step(&x1);
            o2.step(&x2);
        }
        let c1 = o1.b().matmul(&a1);
        let c2 = o2.b().matmul(&a2);
        assert!(
            c1.max_abs_diff(&c2) < 1e-8,
            "equivariance violated: {}",
            c1.max_abs_diff(&c2)
        );
    }

    #[test]
    fn zero_samples_no_state_change() {
        let opt = EasiSgd::with_identity_init(2, 4, 0.01, Nonlinearity::Cube);
        assert_eq!(opt.samples_seen(), 0);
        let mut want = Mat64::eye(2, 4);
        want.scale(0.5);
        assert_eq!(opt.b(), &want);
    }

    #[test]
    fn b_stays_finite_under_random_stream() {
        check("B finite under stream", Config::quick(), |rng| {
            let x_mat = unit_rows(500, 4, rng.next_u64());
            let mut opt = EasiSgd::with_identity_init(2, 4, 0.002, Nonlinearity::Cube);
            for t in 0..x_mat.rows() {
                opt.step(x_mat.row(t));
            }
            opt.b().is_finite()
        });
    }
}
