"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This file is the core correctness signal for the compile path: if these
pass, the HLO artifacts the Rust runtime executes compute exactly the
reference EASI/SMBGD math.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import easi as kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rng(seed):
    return np.random.default_rng(seed)


def rand_problem(seed, n, m, extra=()):
    r = rng(seed)
    B = r.normal(size=(n, m)).astype(np.float32) * 0.5
    xs = [r.normal(size=s).astype(np.float32) for s in extra]
    return (B, *xs)


# ---------------------------------------------------------------------------
# easi_grad_single
# ---------------------------------------------------------------------------

class TestEasiGrad:
    @pytest.mark.parametrize("n,m", [(2, 4), (4, 8), (2, 2), (8, 8), (3, 5)])
    def test_matches_ref(self, n, m):
        B, x = rand_problem(0, n, m, extra=[(m,)])
        got = kernels.easi_grad_single(B, x)
        want = ref.easi_grad(B, x)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_diag_is_y2_minus_1(self):
        # H_ii = y_i^2 - 1 (the antisymmetric g-terms vanish on the diagonal).
        B, x = rand_problem(1, 3, 6, extra=[(6,)])
        H = np.asarray(kernels.easi_grad_single(B, x))
        y = B @ x
        np.testing.assert_allclose(np.diag(H), y * y - 1.0, rtol=1e-5, atol=1e-5)

    def test_zero_input_gives_minus_identity(self):
        B = np.ones((2, 4), np.float32)
        x = np.zeros((4,), np.float32)
        H = np.asarray(kernels.easi_grad_single(B, x))
        np.testing.assert_allclose(H, -np.eye(2, dtype=np.float32))

    def test_nonlinear_part_antisymmetric(self):
        # H + H^T = 2(y y^T - I): the g(y)y^T - y g(y)^T part is antisymmetric.
        B, x = rand_problem(2, 4, 4, extra=[(4,)])
        H = np.asarray(kernels.easi_grad_single(B, x))
        y = B @ x
        sym = H + H.T
        np.testing.assert_allclose(
            sym, 2 * (np.outer(y, y) - np.eye(4)), rtol=1e-4, atol=1e-4
        )

    @settings(max_examples=30, deadline=None)
    @given(
        n=st.integers(1, 8),
        m=st.integers(1, 12),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, n, m, seed):
        if n > m:
            n = m  # ICA requires n <= m
        B, x = rand_problem(seed, n, m, extra=[(m,)])
        got = kernels.easi_grad_single(B, x)
        want = ref.easi_grad(B, x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# easi_sgd_step
# ---------------------------------------------------------------------------

class TestSgdStep:
    @pytest.mark.parametrize("n,m", [(2, 4), (4, 8), (2, 2)])
    def test_matches_ref(self, n, m):
        B, x = rand_problem(3, n, m, extra=[(m,)])
        got = kernels.easi_sgd_step(B, x, 0.01)
        want = ref.easi_sgd_step(B, x, 0.01)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_zero_mu_is_identity(self):
        B, x = rand_problem(4, 2, 4, extra=[(4,)])
        got = kernels.easi_sgd_step(B, x, 0.0)
        np.testing.assert_allclose(got, B, rtol=0, atol=0)

    def test_linear_in_mu_direction(self):
        # B'(mu) = B - mu*H B is affine in mu for fixed (B, x).
        B, x = rand_problem(5, 2, 4, extra=[(4,)])
        b1 = np.asarray(kernels.easi_sgd_step(B, x, 0.01))
        b2 = np.asarray(kernels.easi_sgd_step(B, x, 0.02))
        np.testing.assert_allclose(b2 - B, 2 * (b1 - B), rtol=1e-4, atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 6),
        m=st.integers(1, 8),
        mu=st.floats(0.0, 0.1),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis(self, n, m, mu, seed):
        if n > m:
            n = m
        B, x = rand_problem(seed, n, m, extra=[(m,)])
        got = kernels.easi_sgd_step(B, x, np.float32(mu))
        want = ref.easi_sgd_step(B, x, np.float32(mu))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# smbgd_batch_update
# ---------------------------------------------------------------------------

class TestSmbgdBatch:
    def _args(self, seed, n, m, P, gamma=0.5, beta=0.9, mu=0.01):
        B, Xk = rand_problem(seed, n, m, extra=[(P, m)])
        r = rng(seed + 1)
        Hhat = (r.normal(size=(n, n)) * 0.1).astype(np.float32)
        w = np.asarray(ref.smbgd_weights(P, np.float32(beta), np.float32(mu)))
        carry = np.float32(gamma * beta ** (P - 1))
        return B, Hhat, Xk, w, carry, gamma, beta, mu

    @pytest.mark.parametrize("n,m,P", [(2, 4, 8), (4, 8, 16), (2, 2, 4)])
    def test_matches_closed_form_ref(self, n, m, P):
        B, Hhat, Xk, w, carry, gamma, beta, mu = self._args(7, n, m, P)
        gb, gh = kernels.smbgd_batch_update(B, Hhat, Xk, w, carry)
        wb, wh = ref.smbgd_minibatch_step(
            B, Hhat, Xk, np.float32(gamma), np.float32(beta), np.float32(mu)
        )
        np.testing.assert_allclose(gh, wh, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(gb, wb, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("P", [1, 2, 4, 8, 32])
    def test_matches_sequential_eq1(self, P):
        # The closed form used by the kernel == Eq. 1 run literally.
        n, m = 2, 4
        gamma, beta, mu = 0.6, 0.92, 0.02
        B, Hhat, Xk, w, carry, *_ = self._args(11, n, m, P, gamma, beta, mu)
        _, gh = kernels.smbgd_batch_update(B, Hhat, Xk, w, carry)
        wh = ref.smbgd_hhat_sequential(
            Hhat, B, Xk, np.float32(gamma), np.float32(beta), np.float32(mu)
        )
        np.testing.assert_allclose(gh, wh, rtol=1e-4, atol=1e-5)

    def test_stale_B_within_batch(self):
        # SMBGD's defining property: permuting samples inside a mini-batch
        # changes Hhat (weights differ) but every H^p uses the same B —
        # with beta=1 the result is permutation-invariant.
        n, m, P = 2, 4, 8
        B, Hhat, Xk, _, _, *_ = self._args(13, n, m, P)
        w = np.asarray(ref.smbgd_weights(P, np.float32(1.0), np.float32(0.01)))
        carry = np.float32(0.5)
        _, h1 = kernels.smbgd_batch_update(B, Hhat, Xk, w, carry)
        _, h2 = kernels.smbgd_batch_update(B, Hhat, Xk[::-1].copy(), w, carry)
        np.testing.assert_allclose(h1, h2, rtol=1e-4, atol=1e-5)

    def test_gamma_zero_ignores_prev(self):
        n, m, P = 2, 4, 8
        B, Hhat, Xk, w, _, *_ = self._args(17, n, m, P)
        _, h1 = kernels.smbgd_batch_update(B, Hhat, Xk, w, np.float32(0.0))
        _, h2 = kernels.smbgd_batch_update(
            B, np.zeros_like(Hhat), Xk, w, np.float32(0.0)
        )
        np.testing.assert_allclose(h1, h2, rtol=0, atol=0)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(1, 4),
        m=st.integers(1, 8),
        P=st.integers(1, 16),
        gamma=st.floats(0.0, 1.0),
        beta=st.floats(0.5, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis(self, n, m, P, gamma, beta, seed):
        if n > m:
            n = m
        mu = 0.01
        B, Hhat, Xk, w, carry, *_ = self._args(
            seed, n, m, P, gamma, beta, mu
        )
        gb, gh = kernels.smbgd_batch_update(B, Hhat, Xk, w, carry)
        wb, wh = ref.smbgd_minibatch_step(
            B, Hhat, Xk, np.float32(gamma), np.float32(beta), np.float32(mu)
        )
        np.testing.assert_allclose(gh, wh, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(gb, wb, rtol=1e-3, atol=1e-4)
