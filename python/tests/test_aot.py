"""AOT path coverage: variant inventory, HLO text emission, manifest
format — the contract the Rust runtime depends on."""

import os
import tempfile

import jax
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


class TestVariants:
    def test_inventory_covers_required_programs(self):
        names = [v[0] for v in aot.variants()]
        # The programs the Rust engines / benches select by name.
        for required in [
            "easi_sgd_m4_n2_t64",
            "easi_smbgd_m4_n2_p8_k8",
            "easi_smbgd_m4_n2_p8_k32",
            "easi_smbgd_m4_n2_p16_k16",
            "separate_m4_n2_t256",
            "easi_grad_m4_n2",
        ]:
            assert required in names, f"missing variant {required}"

    def test_names_are_unique(self):
        names = [v[0] for v in aot.variants()]
        assert len(names) == len(set(names))

    def test_manifest_fields_parse_shape(self):
        for name, _fn, _specs, extra in aot.variants():
            assert extra["kind"] in {"sgd", "smbgd", "separate", "grad"}
            assert extra["m"] >= extra["n"] >= 1
            if extra["kind"] == "smbgd":
                assert extra["p"] >= 1 and extra["k"] >= 1

    def test_specs_match_kind_contract(self):
        for name, _fn, specs, extra in aot.variants():
            if extra["kind"] == "sgd":
                assert len(specs) == 3
                assert specs[1].shape == (extra["t"], extra["m"])
            elif extra["kind"] == "smbgd":
                assert len(specs) == 6
                assert specs[2].shape == (extra["k"], extra["p"], extra["m"])


class TestLowering:
    @pytest.mark.parametrize("idx", [0, 2])  # one sgd, one smbgd variant
    def test_lowering_produces_hlo_text(self, idx):
        name, fn, specs, _extra = aot.variants()[idx]
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text, f"{name}: not HLO text"
        assert "ENTRY" in text

    def test_full_emission_round_trip(self):
        with tempfile.TemporaryDirectory() as d:
            import sys
            from unittest import mock

            with mock.patch.object(sys, "argv", ["aot", "--out", d]):
                aot.main()
            files = os.listdir(d)
            assert "manifest.txt" in files
            with open(os.path.join(d, "manifest.txt")) as f:
                lines = [l for l in f.read().splitlines() if l.strip()]
            assert len(lines) == len(aot.variants())
            for line in lines:
                fields = dict(kv.split("=", 1) for kv in line.split())
                assert fields["file"] in files
                # every artifact is parseable HLO text
                with open(os.path.join(d, fields["file"])) as fh:
                    assert fh.read().startswith("HloModule")


class TestScalingVariants:
    def test_m8_variants_shapes(self):
        # The scale-up configuration used by the depth sweep.
        import numpy as np

        B = np.zeros((4, 8), np.float32)
        X = np.zeros((64, 8), np.float32)
        out = model.easi_sgd_chunk(B, X, np.float32(0.001))
        assert out.shape == (4, 8)
