"""L2 correctness: chunked model programs vs sequential oracles, plus
algorithmic sanity (separation actually happens, SMBGD == SGD limits).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rng(seed):
    return np.random.default_rng(seed)


def make_mixtures(seed, n_src, m, T):
    """n_src independent sub-Gaussian sources mixed up to m channels.

    Cubic g(y)=y^3 makes EASI stable only for source pairs with negative
    kurtosis sum (kappa_i = -kurt_i for the cubic), so — like the FPGA/DSP
    EASI literature the paper builds on — we use sub-Gaussian sources:
    uniform (kurt -1.2) and Rademacher +-1 (kurt -2).
    """
    r = rng(seed)
    S = np.empty((T, n_src), np.float32)
    for j in range(n_src):
        if j % 2 == 0:  # sub-Gaussian: uniform, unit variance
            S[:, j] = r.uniform(-np.sqrt(3), np.sqrt(3), size=T)
        else:  # sub-Gaussian: random +-1, unit variance
            S[:, j] = r.integers(0, 2, size=T) * 2.0 - 1.0
    A = r.normal(size=(m, n_src)).astype(np.float32)
    return (S @ A.T).astype(np.float32), A, S


class TestSgdChunk:
    @pytest.mark.parametrize("n,m,T", [(2, 4, 16), (4, 8, 8), (2, 2, 32)])
    def test_matches_sequential_oracle(self, n, m, T):
        r = rng(0)
        B = (r.normal(size=(n, m)) * 0.3).astype(np.float32)
        X = r.normal(size=(T, m)).astype(np.float32)
        got = model.easi_sgd_chunk(B, X, np.float32(0.005))
        want = ref.easi_sgd_chunk(B, X, np.float32(0.005))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_chunking_is_associative(self):
        # Running one 32-chunk == two 16-chunks (coordinator relies on this).
        r = rng(1)
        B = (r.normal(size=(2, 4)) * 0.3).astype(np.float32)
        X = r.normal(size=(32, 4)).astype(np.float32)
        mu = np.float32(0.01)
        whole = model.easi_sgd_chunk(B, X, mu)
        half = model.easi_sgd_chunk(B, X[:16], mu)
        split = model.easi_sgd_chunk(np.asarray(half), X[16:], mu)
        np.testing.assert_allclose(whole, split, rtol=1e-4, atol=1e-5)

    def test_pallas_matches_pure_jnp_path(self):
        r = rng(2)
        B = (r.normal(size=(2, 4)) * 0.3).astype(np.float32)
        X = r.normal(size=(64, 4)).astype(np.float32)
        mu = np.float32(0.01)
        a = model.easi_sgd_chunk(B, X, mu)
        b = model.ref_sgd_chunk(B, X, mu)
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


class TestSmbgdChunk:
    def test_matches_sequential_oracle(self):
        r = rng(3)
        n, m, K, P = 2, 4, 4, 8
        B = (r.normal(size=(n, m)) * 0.3).astype(np.float32)
        Hh = np.zeros((n, n), np.float32)
        X = r.normal(size=(K, P, m)).astype(np.float32)
        g, b_, mu = np.float32(0.5), np.float32(0.9), np.float32(0.01)
        gb, gh = model.easi_smbgd_chunk(B, Hh, X, g, b_, mu)
        wb, wh = ref.smbgd_chunk(B, Hh, X, g, b_, mu)
        np.testing.assert_allclose(gb, wb, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(gh, wh, rtol=1e-3, atol=1e-4)

    def test_chunking_carries_hhat(self):
        # Two chunks of K=2 == one chunk of K=4 only if Hhat is carried —
        # this is the contract between coordinator chunks.
        r = rng(4)
        n, m, K, P = 2, 4, 4, 8
        B = (r.normal(size=(n, m)) * 0.3).astype(np.float32)
        Hh = np.zeros((n, n), np.float32)
        X = r.normal(size=(K, P, m)).astype(np.float32)
        g, b_, mu = np.float32(0.7), np.float32(0.95), np.float32(0.005)
        wb, wh = model.easi_smbgd_chunk(B, Hh, X, g, b_, mu)
        b1, h1 = model.easi_smbgd_chunk(B, Hh, X[:2], g, b_, mu)
        b2, h2 = model.easi_smbgd_chunk(
            np.asarray(b1), np.asarray(h1), X[2:], g, b_, mu
        )
        np.testing.assert_allclose(wb, b2, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(wh, h2, rtol=1e-4, atol=1e-5)

    def test_p1_beta_anything_equals_sgd_with_momentum_off(self):
        # P=1, gamma=0: each "mini-batch" is one sample and the update
        # degenerates to plain SGD.
        r = rng(5)
        n, m, T = 2, 4, 16
        B = (r.normal(size=(n, m)) * 0.3).astype(np.float32)
        X = r.normal(size=(T, m)).astype(np.float32)
        mu = np.float32(0.01)
        gb, _ = model.easi_smbgd_chunk(
            B,
            np.zeros((n, n), np.float32),
            X.reshape(T, 1, m),
            np.float32(0.0),
            np.float32(0.9),
            mu,
        )
        want = ref.easi_sgd_chunk(B, X, mu)
        np.testing.assert_allclose(gb, want, rtol=1e-3, atol=1e-4)


class TestSeparation:
    """End-to-end algorithmic checks: the model programs actually separate."""

    def _amari_after(self, opt, seed, T=6000):
        n, m = 2, 4
        X, A, _ = make_mixtures(seed, n, m, T)
        # scale down mixtures for stability (the coordinator normalizes too)
        X = X / np.std(X)
        r = rng(seed + 100)
        B = (np.eye(n, m) + 0.1 * r.normal(size=(n, m))).astype(np.float32) * 0.5
        mu = np.float32(0.002)
        if opt == "sgd":
            for i in range(0, T, 256):
                chunk = X[i : i + 256]
                if len(chunk) < 256:
                    break
                B = np.asarray(model.easi_sgd_chunk(B, chunk, mu))
        else:
            Hh = np.zeros((n, n), np.float32)
            P, K = 8, 16
            step = P * K
            g, b_ = np.float32(0.5), np.float32(0.9)
            for i in range(0, T, step):
                chunk = X[i : i + step]
                if len(chunk) < step:
                    break
                B, Hh = model.easi_smbgd_chunk(
                    B, Hh, chunk.reshape(K, P, m), g, b_, mu
                )
                B, Hh = np.asarray(B), np.asarray(Hh)
        C = B @ A[:, :n]  # global matrix restricted to true sources
        return float(ref.amari_index(jnp.asarray(C)))

    def test_sgd_separates(self):
        assert self._amari_after("sgd", 0) < 0.25

    def test_smbgd_separates(self):
        assert self._amari_after("smbgd", 0) < 0.25


class TestSeparateChunk:
    def test_projects(self):
        r = rng(6)
        B = r.normal(size=(2, 4)).astype(np.float32)
        X = r.normal(size=(8, 4)).astype(np.float32)
        Y = model.separate_chunk(B, X)
        np.testing.assert_allclose(Y, X @ B.T, rtol=1e-6, atol=1e-6)


class TestSmbgdChunkHypothesis:
    """Shape/parameter sweeps of the L2 smbgd chunk program against the
    sequential oracle (the program the Rust engine executes)."""

    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(
        k=st.integers(1, 6),
        p=st.integers(1, 12),
        n=st.integers(1, 4),
        extra_m=st.integers(0, 4),
        gamma=st.floats(0.0, 1.0),
        beta=st.floats(0.6, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_chunk_matches_oracle(self, k, p, n, extra_m, gamma, beta, seed):
        m = n + extra_m
        r = rng(seed)
        B = (r.normal(size=(n, m)) * 0.3).astype(np.float32)
        Hh = (r.normal(size=(n, n)) * 0.05).astype(np.float32)
        X = r.normal(size=(k, p, m)).astype(np.float32)
        g, b_, mu = np.float32(gamma), np.float32(beta), np.float32(0.005)
        gb, gh = model.easi_smbgd_chunk(B, Hh, X, g, b_, mu)
        wb, wh = ref.smbgd_chunk(B, Hh, X, g, b_, mu)
        np.testing.assert_allclose(gb, wb, rtol=2e-3, atol=2e-4)
        np.testing.assert_allclose(gh, wh, rtol=2e-3, atol=2e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        t=st.integers(1, 48),
        n=st.integers(1, 4),
        extra_m=st.integers(0, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_sgd_chunk_matches_oracle(self, t, n, extra_m, seed):
        m = n + extra_m
        r = rng(seed)
        B = (r.normal(size=(n, m)) * 0.3).astype(np.float32)
        X = r.normal(size=(t, m)).astype(np.float32)
        got = model.easi_sgd_chunk(B, X, np.float32(0.004))
        want = ref.easi_sgd_chunk(B, X, np.float32(0.004))
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-4)
