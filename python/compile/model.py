"""Layer-2 JAX model: chunked EASI training programs built on the L1 kernels.

These are the computations that get AOT-lowered (by `aot.py`) to HLO text
and executed from the Rust coordinator via PJRT.  Python never runs on the
request path: each function here is a pure, fixed-shape program.

Two programs, mirroring the paper's two architectures:

  easi_sgd_chunk    — Fig. 1: T sequential per-sample updates.  The
                      `lax.scan` carry on B *is* the loop-carried
                      dependency the paper complains about; on TPU it
                      serializes exactly like the stalled FPGA pipeline.
  easi_smbgd_chunk  — Fig. 2: K mini-batches of P samples.  Each
                      mini-batch is ONE fused Pallas kernel call (batched
                      MXU matmuls); only the K-loop is sequential.

Both are exposed chunked (T or K*P samples per call) so the Rust
coordinator can interleave streaming, metric computation, and state
snapshots between calls.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import easi as kernels
from compile.kernels import ref


def easi_sgd_chunk(B, X, mu):
    """T sequential vanilla-EASI updates (Fig. 1 program).

    Args:
      B:  (n, m) f32 separation matrix.
      X:  (T, m) f32 samples, consumed in order.
      mu: () f32 learning rate.

    Returns:
      (n, m) f32 updated separation matrix.
    """

    def step(Bc, x):
        return kernels.easi_sgd_step(Bc, x, mu), None

    Bf, _ = jax.lax.scan(step, B, X)
    return Bf


def easi_smbgd_chunk(B, Hhat, X, gamma, beta, mu):
    """K sequential SMBGD mini-batch updates (Fig. 2 program).

    Args:
      B:     (n, m) f32 separation matrix.
      Hhat:  (n, n) f32 Eq. 1 accumulator (zeros at stream start).
      X:     (K, P, m) f32 samples grouped into K mini-batches.
      gamma: () f32 cross-batch momentum coefficient.
      beta:  () f32 intra-batch decay coefficient.
      mu:    () f32 learning rate.

    Returns:
      (B', Hhat'): updated matrix and accumulator, to be carried into the
      next chunk by the Rust coordinator.
    """
    P = X.shape[1]
    dt = B.dtype
    # Closed-form Eq. 1 constants (see ref.smbgd_weights).
    p = jnp.arange(P, dtype=dt)
    w = mu * beta ** (P - 1 - p)
    carry = gamma * beta ** (P - 1)

    def step(state, Xk):
        Bc, Hc = state
        Bn, Hn = kernels.smbgd_batch_update(Bc, Hc, Xk, w, carry)
        return (Bn, Hn), None

    (Bf, Hf), _ = jax.lax.scan(step, (B, Hhat), X)
    return Bf, Hf


def easi_grad(B, x):
    """Single-sample relative gradient H (exported for runtime tests)."""
    return kernels.easi_grad_single(B, x)


def separate_chunk(B, X):
    """Inference-only program: Y = X B^T for a chunk of samples.

    This is the 'deployment' half of the paper's create/train/deploy
    hardware: applying the current separation matrix to a block of
    samples without updating it.
    """
    return X @ B.T


def ref_sgd_chunk(B, X, mu):
    """Pure-jnp (no pallas) variant of easi_sgd_chunk, used for parity
    tests and as the XLA-fusion baseline in the perf pass."""

    def step(Bc, x):
        return ref.easi_sgd_step(Bc, x, mu), None

    Bf, _ = jax.lax.scan(step, B, X)
    return Bf
