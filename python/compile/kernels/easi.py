"""Layer-1 Pallas kernels for EASI / SMBGD.

Hardware adaptation (DESIGN.md SSHardware-Adaptation): the paper's FPGA
contribution is *break the loop-carried dependency so the datapath can be
pipelined with initiation interval 1*.  On TPU the same insight becomes
*batch the mini-batch into one MXU matmul*: because SMBGD evaluates every
sample in a mini-batch against the same stale separation matrix B, the P
per-sample mat-vecs `y_p = B x_p` collapse into a single `(P,m)x(m,n)`
matmul, and the P weighted outer-product accumulations of Eq. 1 collapse
into three `(n,P)x(P,n)` matmuls with the exponentially-decaying weights
folded into one operand.  Plain SGD-EASI cannot do this — its scan over
samples is serialized exactly like the stalled FPGA pipeline.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is the executable path and the
Mosaic path is compile-only (see /opt/xla-example/README.md).  VMEM
budgeting for a real TPU is documented in DESIGN.md SS7.

Kernels:
  easi_grad_single   — H for one sample (Fig. 1's gradient block).
  easi_sgd_step      — one fused SGD update B <- B - mu H B.
  smbgd_batch_update — one fused SMBGD mini-batch (Fig. 2): batched
                       gradient + Eq. 1 accumulation + single B update.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Every pallas_call in this module uses interpret mode (see module doc).
INTERPRET = True


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------

def _easi_grad_kernel(b_ref, x_ref, h_ref):
    """H = y y^T - I + g(y) y^T - y g(y)^T for one sample, in VMEM.

    b_ref: (n, m), x_ref: (1, m), h_ref: (n, n).
    """
    B = b_ref[...]
    x = x_ref[0, :]
    y = B @ x                      # (n,) mat-vec on the MXU
    gy = y * y * y                 # cubic nonlinearity: two VPU multiplies
    n = B.shape[0]
    yc = y[:, None]
    gc = gy[:, None]
    # outer products as (n,1)x(1,n) matmuls
    h_ref[...] = (
        yc * y[None, :]
        - jnp.eye(n, dtype=B.dtype)
        + gc * y[None, :]
        - yc * gy[None, :]
    )


def _easi_sgd_step_kernel(b_ref, x_ref, mu_ref, o_ref):
    """Fused vanilla-EASI update: o = B - mu * H(B, x) B.

    Keeping H in registers/VMEM and fusing the trailing H @ B avoids a
    round-trip of the (n, n) gradient through HBM.
    """
    B = b_ref[...]
    x = x_ref[0, :]
    mu = mu_ref[0, 0]
    y = B @ x
    gy = y * y * y
    n = B.shape[0]
    yc = y[:, None]
    gc = gy[:, None]
    H = (
        yc * y[None, :]
        - jnp.eye(n, dtype=B.dtype)
        + gc * y[None, :]
        - yc * gy[None, :]
    )
    o_ref[...] = B - mu * (H @ B)


def _smbgd_batch_update_kernel(b_ref, hhat_ref, x_ref, w_ref, carry_ref,
                               b_out_ref, hhat_out_ref):
    """Fused SMBGD mini-batch (Fig. 2 / Eq. 1, closed form).

      Y    = X B^T                       (P,n)   one MXU matmul
      G    = Y**3                        (P,n)   VPU
      Hhat = carry * Hhat_prev
           + (w*Y)^T Y - (sum w) I + (w*G)^T Y - Y^T (w*G)
      B'   = B - Hhat B

    b_ref: (n, m), hhat_ref: (n, n), x_ref: (P, m), w_ref: (1, P)
    (w_p = mu * beta**(P-1-p)), carry_ref: (1, 1) (= gamma * beta**(P-1)).

    The whole mini-batch stays resident in VMEM: for the paper's scale
    (m=4, n=2, P<=64) the footprint is a few KB, far under the ~16 MB
    VMEM budget; for large P the natural extension is a grid over P-tiles
    accumulating into hhat_out_ref.
    """
    B = b_ref[...]
    Hhat_prev = hhat_ref[...]
    X = x_ref[...]
    w = w_ref[0, :]
    carry = carry_ref[0, 0]

    Y = X @ B.T                    # (P, n): the P mat-vecs as ONE matmul
    G = Y * Y * Y
    Yw = Y * w[:, None]            # fold Eq. 1's decaying weights in
    Gw = G * w[:, None]
    n = B.shape[0]
    I = jnp.eye(n, dtype=B.dtype)
    contrib = Yw.T @ Y - jnp.sum(w) * I + Gw.T @ Y - Y.T @ Gw
    Hhat = carry * Hhat_prev + contrib
    hhat_out_ref[...] = Hhat
    b_out_ref[...] = B - Hhat @ B


# ---------------------------------------------------------------------------
# Public entry points (shape-checked pallas_call wrappers)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=())
def easi_grad_single(B, x):
    """H(B, x) for one sample via the Pallas kernel.

    Args: B (n, m) f32; x (m,) f32.  Returns H (n, n) f32.
    """
    n, m = B.shape
    return pl.pallas_call(
        _easi_grad_kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), B.dtype),
        interpret=INTERPRET,
    )(B, x.reshape(1, m))


@jax.jit
def easi_sgd_step(B, x, mu):
    """One fused SGD update via the Pallas kernel.

    Args: B (n, m) f32; x (m,) f32; mu scalar f32.  Returns B' (n, m).
    """
    n, m = B.shape
    mu_arr = jnp.asarray(mu, dtype=B.dtype).reshape(1, 1)
    return pl.pallas_call(
        _easi_sgd_step_kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), B.dtype),
        interpret=INTERPRET,
    )(B, x.reshape(1, m), mu_arr)


@jax.jit
def smbgd_batch_update(B, Hhat, Xk, w, carry):
    """One fused SMBGD mini-batch update via the Pallas kernel.

    Args:
      B:    (n, m) separation matrix (stale for the whole mini-batch).
      Hhat: (n, n) accumulator carried from the previous mini-batch.
      Xk:   (P, m) mini-batch samples.
      w:    (P,) per-sample weights  mu * beta**(P-1-p).
      carry: scalar  gamma * beta**(P-1).

    Returns: (B', Hhat') — matching `ref.smbgd_minibatch_step`.
    """
    n, m = B.shape
    P = Xk.shape[0]
    carry_arr = jnp.asarray(carry, dtype=B.dtype).reshape(1, 1)
    return pl.pallas_call(
        _smbgd_batch_update_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n, m), B.dtype),
            jax.ShapeDtypeStruct((n, n), B.dtype),
        ),
        interpret=INTERPRET,
    )(B, Hhat, Xk, w.reshape(1, P), carry_arr)
