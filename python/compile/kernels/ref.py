"""Pure-jnp reference oracle for the EASI / SMBGD kernels.

This module is the CORE correctness signal for Layer 1: every Pallas kernel
in `easi.py` and every Layer-2 model function in `model.py` is pinned to
these definitions by pytest (see python/tests/).  Everything here follows
the paper's notation:

  y   = B x                      (estimated components, n-vector)
  g(y)= y**3                     (cubic nonlinearity, paper SS V.B)
  H   = y y^T - I + g(y) y^T - y g(y)^T     (EASI relative gradient [9])
  SGD:    B <- B - mu * H B                 (vanilla EASI, Fig. 1)
  SMBGD:  Eq. 1 of the paper (Fig. 2), see `smbgd_hhat_sequential`.

The reference implementations are deliberately written in the most
literal/sequential way possible (per-sample loops, explicit Eq. 1
recurrence) so that the closed-form, batched formulations used by the
Pallas kernels are tested against something independently simple.
"""

from __future__ import annotations

import jax.numpy as jnp


def cube(y):
    """The paper's nonlinearity g(y) = y^3 (elementwise)."""
    return y * y * y


def easi_grad(B, x, g=cube):
    """EASI relative gradient H for one sample.

    Args:
      B: (n, m) separation matrix.
      x: (m,) one input-feature sample.
      g: elementwise nonlinearity (default: the paper's cubic).

    Returns:
      H: (n, n) relative gradient  y y^T - I + g(y) y^T - y g(y)^T.
    """
    y = B @ x
    gy = g(y)
    n = B.shape[0]
    return (
        jnp.outer(y, y)
        - jnp.eye(n, dtype=B.dtype)
        + jnp.outer(gy, y)
        - jnp.outer(y, gy)
    )


def easi_sgd_step(B, x, mu, g=cube):
    """One vanilla-EASI SGD update: B <- B - mu * H(B, x) B."""
    H = easi_grad(B, x, g)
    return B - mu * (H @ B)


def easi_sgd_chunk(B, X, mu, g=cube):
    """T sequential SGD updates (python loop — the literal oracle).

    Args:
      B: (n, m) initial separation matrix.
      X: (T, m) samples, consumed in order (loop-carried dependency).
      mu: scalar learning rate.

    Returns:
      (n, m) updated separation matrix after all T samples.
    """
    for t in range(X.shape[0]):
        B = easi_sgd_step(B, X[t], mu, g)
    return B


def smbgd_weights(P, beta, mu, dtype=jnp.float32):
    """Closed-form per-sample weights of Eq. 1 within one mini-batch.

    Unrolling Eq. 1 for p = 0..P-1 gives

      Hhat_final = beta**(P-1) * gamma * Hhat_prev
                 + sum_p  mu * beta**(P-1-p) * H^p

    so sample p carries weight  w_p = mu * beta**(P-1-p)  and the previous
    mini-batch's accumulator carries  carry = beta**(P-1) * gamma.
    """
    p = jnp.arange(P, dtype=dtype)
    return mu * beta ** (P - 1 - p)


def smbgd_hhat_sequential(Hhat_prev, B, Xk, gamma, beta, mu, g=cube):
    """Eq. 1, computed exactly as written (sequential recurrence).

      p = 0:      Hhat = gamma * Hhat_prev + mu * H^0
      0 < p < P:  Hhat = beta * Hhat      + mu * H^p

    All H^p are evaluated against the SAME (stale) B — this is the whole
    point of SMBGD: it breaks the loop-carried dependency on B.

    Args:
      Hhat_prev: (n, n) final accumulator of the previous mini-batch
        (zeros for the first mini-batch, i.e. gamma is effectively 0).
      B: (n, m) separation matrix (constant within the mini-batch).
      Xk: (P, m) the mini-batch samples.

    Returns:
      (n, n) Hhat after the last sample of the mini-batch.
    """
    P = Xk.shape[0]
    Hhat = gamma * Hhat_prev + mu * easi_grad(B, Xk[0], g)
    for p in range(1, P):
        Hhat = beta * Hhat + mu * easi_grad(B, Xk[p], g)
    return Hhat


def smbgd_batch_contrib(B, Xk, w, g=cube):
    """Closed-form weighted gradient contribution of one mini-batch.

    sum_p w_p H^p
      = (w*Y)^T Y - (sum w) I + (w*G)^T Y - Y^T (w*G)     with
    Y = Xk B^T (P, n), G = g(Y).

    This is the MXU-friendly formulation the Pallas kernel implements:
    the per-sample outer products collapse into three (n x P)(P x n)
    matmuls with the weights folded into one operand.
    """
    Y = Xk @ B.T            # (P, n)
    G = g(Y)                # (P, n)
    Yw = Y * w[:, None]     # weights folded into one operand
    Gw = G * w[:, None]
    n = B.shape[0]
    I = jnp.eye(n, dtype=B.dtype)
    return Yw.T @ Y - jnp.sum(w) * I + Gw.T @ Y - Y.T @ Gw


def smbgd_minibatch_step(B, Hhat_prev, Xk, gamma, beta, mu, g=cube):
    """One full SMBGD mini-batch: accumulate Eq. 1, then update B once.

    Returns (B_next, Hhat_final):
      Hhat_final = beta**(P-1) * gamma * Hhat_prev + sum_p w_p H^p
      B_next     = B - Hhat_final B
    """
    P = Xk.shape[0]
    w = smbgd_weights(P, beta, mu, dtype=B.dtype)
    carry = beta ** (P - 1) * gamma
    Hhat = carry * Hhat_prev + smbgd_batch_contrib(B, Xk, w, g)
    return B - Hhat @ B, Hhat


def smbgd_chunk(B, Hhat, X, gamma, beta, mu, g=cube):
    """K sequential mini-batches (python loop oracle).

    Args:
      X: (K, P, m) samples grouped into K mini-batches of P.

    Returns:
      (B, Hhat) after all K mini-batches.
    """
    for k in range(X.shape[0]):
        B, Hhat = smbgd_minibatch_step(B, Hhat, X[k], gamma, beta, mu, g)
    return B, Hhat


def amari_index(C):
    """Amari performance index of the global matrix C = B A (n x n).

    0 when C is a scaled permutation (perfect separation); used by tests
    and mirrored by the Rust implementation in `ica::metrics`.
    """
    C = jnp.abs(C)
    n = C.shape[0]
    row = jnp.sum(C / jnp.max(C, axis=1, keepdims=True), axis=1) - 1.0
    col = jnp.sum(C / jnp.max(C, axis=0, keepdims=True), axis=0) - 1.0
    return (jnp.sum(row) + jnp.sum(col)) / (2.0 * n * (n - 1))
