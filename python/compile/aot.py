"""AOT compile path: lower the Layer-2 programs to HLO text artifacts.

Run as:  cd python && python -m compile.aot --out ../artifacts

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each variant is written to `<name>.hlo.txt` and described by one line in
`manifest.txt` with a trivially hand-parseable `key=value` format (the
Rust side has no serde):

    name=easi_smbgd_m4_n2_p8_k8 file=... kind=smbgd m=4 n=2 p=8 k=8

Artifacts are deterministic functions of this package's sources; the
Makefile only re-runs this module when the sources change.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def variants():
    """(name, fn, example_args, manifest-extras) for every artifact.

    (m, n) = (4, 2) is the paper's Table I configuration; (8, 4) is the
    scale-up used by the depth-sweep and coordinator tests.  Chunk sizes
    are fixed shapes: the Rust coordinator pads the tail of a stream.
    """
    out = []
    for (m, n) in [(4, 2), (8, 4)]:
        for T in [64, 256]:
            out.append((
                f"easi_sgd_m{m}_n{n}_t{T}",
                model.easi_sgd_chunk,
                (_spec(n, m), _spec(T, m), _spec()),
                {"kind": "sgd", "m": m, "n": n, "t": T},
            ))
        for (K, P) in [(8, 8), (32, 8), (16, 16)]:
            out.append((
                f"easi_smbgd_m{m}_n{n}_p{P}_k{K}",
                model.easi_smbgd_chunk,
                (_spec(n, m), _spec(n, n), _spec(K, P, m), _spec(), _spec(), _spec()),
                {"kind": "smbgd", "m": m, "n": n, "p": P, "k": K},
            ))
        out.append((
            f"separate_m{m}_n{n}_t256",
            model.separate_chunk,
            (_spec(n, m), _spec(256, m)),
            {"kind": "separate", "m": m, "n": n, "t": 256},
        ))
        out.append((
            f"easi_grad_m{m}_n{n}",
            model.easi_grad,
            (_spec(n, m), _spec(m)),
            {"kind": "grad", "m": m, "n": n},
        ))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts directory (or a .../model.hlo.txt path, "
                         "whose parent is used)")
    args = ap.parse_args()
    out_dir = args.out
    if out_dir.endswith(".txt"):
        out_dir = os.path.dirname(out_dir) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest_lines = []
    for name, fn, specs, extra in variants():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        fields = " ".join(f"{k}={v}" for k, v in extra.items())
        manifest_lines.append(f"name={name} file={fname} {fields}")
        print(f"wrote {fname} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    # Marker consumed by the Makefile's up-to-date check.
    with open(os.path.join(out_dir, "model.hlo.txt"), "w") as f:
        f.write("# see manifest.txt; individual programs are <name>.hlo.txt\n")
    print(f"wrote manifest.txt ({len(manifest_lines)} programs)")


if __name__ == "__main__":
    main()
